"""Storage crash-consistency and the vectorized pk index: flush -> reload
round trips (counts / index / get / per-segment lineage / zone maps
agree), recovery from pre-lineage manifests, the insert-path semantics
the sorted-array index must preserve bit-for-bit vs the old per-row dict
loop, and the compaction primitives (dead-row accounting, renumbering,
epoch fencing, conditional deletes).

Deliberately hypothesis-free: runs in the minimal-install CI job.
"""

import json
import os

import numpy as np
import pytest

from repro.core import StorageJob, StoragePartition
from repro.core.records import SyntheticTweets, parse_json_lines
from repro.core.storage import _PkIndex, merge_lineage


def batch_of(n, seed=1, start_id=0):
    return parse_json_lines(
        SyntheticTweets(seed=seed, start_id=start_id).raw_lines(n))


# ---------------------------------------------------------------------------
# the vectorized pk index (satellite: no per-row Python loop on insert)
# ---------------------------------------------------------------------------

def test_pk_index_lookup_contains_put():
    ix = _PkIndex()
    assert not ix.contains(np.array([1, 2])).any()
    ix.put(np.array([5, 3, 9]), np.array([0, 1, 2]))
    assert ix.lookup(np.array([3, 5, 9, 4])).tolist() == [1, 0, 2, -1]
    ix.put(np.array([3, 7]), np.array([10, 11]))       # update + insert
    assert ix.lookup(np.array([3, 7])).tolist() == [10, 11]
    assert len(ix) == 4
    assert ix.get(9) == 2
    assert ix.get(1000) is None


def test_pk_index_within_batch_duplicates_last_wins():
    ix = _PkIndex()
    ix.put(np.array([4, 4, 4, 2]), np.array([0, 1, 2, 3]))
    assert ix.get(4) == 2                              # last occurrence
    assert ix.get(2) == 3
    assert len(ix) == 2


def test_insert_mode_skips_duplicates_upsert_remaps():
    p = StoragePartition(0)
    b = batch_of(50)
    assert p.insert(b, upsert=False) == 50
    assert p.insert(b, upsert=False) == 0              # idempotent redelivery
    assert p.count == 50
    # upsert mode: rows re-append, index remaps, count unchanged
    b2 = dict(b)
    b2["country"] = b["country"] + 1
    assert p.insert(b2, upsert=True) == 0              # nothing NEW stored
    assert p.count == 50
    pk = int(b["id"][7])
    assert int(p.get(pk)["country"]) == int(b["country"][7]) + 1


def test_insert_respects_valid_mask():
    p = StoragePartition(0)
    b = batch_of(20)
    b["valid"][10:] = False
    assert p.insert(b, upsert=False) == 10
    assert p.count == 10
    assert p.get(int(b["id"][15])) is None


# ---------------------------------------------------------------------------
# crash-consistency round trip, incl. lineage
# ---------------------------------------------------------------------------

def test_recover_round_trip_counts_index_get_lineage(tmp_path):
    sj = StorageJob(2, spill_dir=str(tmp_path), segment_rows=40)
    b1, b2 = batch_of(60, seed=2), batch_of(60, seed=3, start_id=1000)
    sj.write(b1, lineage={"safety_levels": 3})
    sj.write(b2, lineage={"safety_levels": 5})
    sj.flush()
    before = {p.pid: (p.count, p.lineage_units())
              for p in sj.partitions}

    fresh = StorageJob(2, spill_dir=str(tmp_path)).recover()
    assert fresh.count == sj.count == 120
    for p in fresh.partitions:
        want_count, want_units = before[p.pid]
        assert p.count == want_count
        assert p.lineage_units() == want_units
        # every flushed unit carries the (min-merged) lineage
        for _, _, lin in p.lineage_units():
            assert lin.get("safety_levels") in (3, 5)
    # point lookups agree with the original content
    for b in (b1, b2):
        for i in range(0, 60, 7):
            pk = int(b["id"][i])
            row = fresh.get(pk)
            assert row is not None
            assert int(row["country"]) == int(b["country"][i])


def test_recover_upsert_latest_wins_across_segments(tmp_path):
    p = StoragePartition(0, spill_dir=str(tmp_path), segment_rows=10)
    b = batch_of(10, seed=4)
    p.insert(b, upsert=True, lineage={"t": 1})         # -> segment 0
    b2 = {k: v.copy() for k, v in b.items()}
    b2["country"] = b["country"] + 100
    p.insert(b2, upsert=True, lineage={"t": 2})        # -> segment 1
    p.flush()
    fresh = StoragePartition(0, spill_dir=str(tmp_path)).recover()
    assert fresh.count == 10
    pk = int(b["id"][3])
    assert int(fresh.get(pk)["country"]) == int(b["country"][3]) + 100
    lins = [lin for _, _, lin in fresh.lineage_units()]
    assert lins == [{"t": 1}, {"t": 2}]


def test_recover_pre_lineage_manifest(tmp_path):
    """Old-format manifests (no seg_rows/lineage) recover with empty
    lineage — treated always-stale by repair, which is the safe side."""
    p = StoragePartition(0, spill_dir=str(tmp_path), segment_rows=10)
    p.insert(batch_of(10, seed=5), upsert=False, lineage={"t": 7})
    p.flush()
    man = os.path.join(str(tmp_path), "p0", "MANIFEST.json")
    with open(man) as f:
        manifest = json.load(f)
    with open(man, "w") as f:
        json.dump({"segments": manifest["segments"],
                   "rows": manifest["rows"]}, f)
    fresh = StoragePartition(0, spill_dir=str(tmp_path)).recover()
    assert fresh.count == 10
    assert [lin for _, _, lin in fresh.lineage_units()] == [{}]


def test_recover_without_manifest_is_empty(tmp_path):
    p = StoragePartition(0, spill_dir=str(tmp_path))
    p.insert(batch_of(5), upsert=False)                # buffered, no flush
    fresh = StoragePartition(0, spill_dir=str(tmp_path)).recover()
    assert fresh.count == 0                            # unflushed rows lost
    assert fresh.lineage_units() == []


def test_recover_requires_spill_dir():
    with pytest.raises(RuntimeError, match="spill_dir"):
        StoragePartition(0).recover()


# ---------------------------------------------------------------------------
# lineage bookkeeping helpers
# ---------------------------------------------------------------------------

def test_merge_lineage_oldest_wins_and_none_drops():
    assert merge_lineage([{"a": 3, "b": 9}, {"a": 5, "b": 2}]) == \
        {"a": 3, "b": 2}
    assert merge_lineage([{"a": 3}, {"a": 5, "b": 2}]) == {"a": 3}
    assert merge_lineage([{"a": 3}, None]) == {}
    assert merge_lineage([]) == {}


def test_flush_merges_chunk_lineage_min(tmp_path):
    p = StoragePartition(0, spill_dir=str(tmp_path), segment_rows=1000)
    p.insert(batch_of(10, seed=6), upsert=False, lineage={"t": 4})
    p.insert(batch_of(10, seed=7), upsert=False, lineage={"t": 9})
    p.flush()
    assert [lin for _, _, lin in p.lineage_units()] == [{"t": 4}]


def test_read_rows_spans_segments_and_chunks(tmp_path):
    p = StoragePartition(0, spill_dir=str(tmp_path), segment_rows=10)
    b1 = batch_of(10, seed=8)
    b2 = batch_of(6, seed=9, start_id=1000)
    p.insert(b1, upsert=False, lineage={"t": 1})       # flushed
    p.insert(b2, upsert=False, lineage={"t": 2})       # buffered
    got = p.read_rows(5, 8)                            # 5 from seg + 3 chunk
    assert got["id"].shape[0] == 8
    np.testing.assert_array_equal(got["id"][:5], b1["id"][5:])
    np.testing.assert_array_equal(got["id"][5:], b2["id"][:3])


def test_zone_maps_flush_recover_round_trip(tmp_path):
    """Satellite: zone maps persist in the manifest at flush and recover
    bit-for-bit; pre-zone-map manifests recover with none (never
    pruned)."""
    p = StoragePartition(0, spill_dir=str(tmp_path), segment_rows=10)
    b = batch_of(10, seed=21)
    p.insert(b, upsert=False, lineage={"t": 1})
    p.flush()
    with p._lock:
        want = list(p._seg_zmaps)
    assert want[0]["id"] == (int(b["id"].min()), int(b["id"].max()))
    assert want[0]["lat"] == (float(b["lat"].min()), float(b["lat"].max()))
    assert "text_tokens" not in want[0]            # 2-D: not range-prunable
    assert "valid" not in want[0]                  # bool: not range-prunable
    fresh = StoragePartition(0, spill_dir=str(tmp_path)).recover()
    with fresh._lock:
        got = list(fresh._seg_zmaps)
    assert got == want
    # legacy manifest: no zone_maps key
    man = os.path.join(str(tmp_path), "p0", "MANIFEST.json")
    with open(man) as f:
        manifest = json.load(f)
    del manifest["zone_maps"]
    with open(man, "w") as f:
        json.dump(manifest, f)
    legacy = StoragePartition(0, spill_dir=str(tmp_path)).recover()
    with legacy._lock:
        assert legacy._seg_zmaps == [{}]
    # compact_segment with zero dead rows rebuilds the missing zone maps
    # in place (no rewrite, no epoch bump)
    assert legacy.compact_segment(0) == 0
    assert legacy.epoch == 0
    with legacy._lock:
        assert legacy._seg_zmaps == want


def test_zone_map_cols_selects_and_sorts(tmp_path):
    p = StoragePartition(0, spill_dir=str(tmp_path), segment_rows=10,
                         zone_map_cols=("country",), sort_key="country")
    b = batch_of(10, seed=22)
    p.insert(b, upsert=False, lineage={"t": 1})
    p.flush()
    fresh = StoragePartition(0, spill_dir=str(tmp_path),
                             zone_map_cols=("country",),
                             sort_key="country").recover()
    snap = fresh.snapshot_view()
    try:
        assert set(snap.units[0].zone_map) == {"country"}
        cols = snap.units[0].read(("id", "country"))
        assert (np.diff(cols["country"]) >= 0).all()
        assert snap.live_mask(cols["id"], 0).all()
    finally:
        snap.release()
    for i in range(10):                        # index follows the sort
        pk = int(b["id"][i])
        assert int(fresh.get(pk)["country"]) == int(b["country"][i])


def test_compaction_recover_round_trip_and_dead_recount(tmp_path):
    p = StoragePartition(0, spill_dir=str(tmp_path), segment_rows=10)
    b = batch_of(10, seed=23)
    p.insert(b, upsert=True, lineage={"t": 1})
    b2 = {k: v.copy() for k, v in b.items()}
    b2["country"] = b["country"] + 7
    p.insert(b2, upsert=True, lineage={"t": 2})    # segment 0 fully dead
    p.flush()
    assert p.dead_rows == 10
    # recovery recomputes dead counters exactly from the rebuilt index
    fresh = StoragePartition(0, spill_dir=str(tmp_path)).recover()
    assert fresh.dead_rows == 10
    assert fresh.compact() == 10
    assert fresh.dead_rows == 0 and fresh.count == 10
    assert fresh.rows_total == 10
    # and the compacted layout itself round-trips
    again = StoragePartition(0, spill_dir=str(tmp_path)).recover()
    assert again.count == 10 and again.dead_rows == 0
    pk = int(b["id"][4])
    assert int(again.get(pk)["country"]) == int(b["country"][4]) + 7
    # the fully-dead segment is gone, not kept as a 0-row unit
    assert [lin for _, _, lin in again.lineage_units()] == [{"t": 2}]


def test_delete_rows_conditional_and_epoch_fencing():
    p = StoragePartition(0)
    b = batch_of(10, seed=24)
    p.insert(b, upsert=True, lineage={"t": 1})
    scanned = np.arange(3)
    # a racing ingest upsert supersedes row 0: the delete must spare it
    newer = {k: v[:1].copy() for k, v in b.items()}
    p.insert(newer, upsert=True, lineage={"t": 2})
    assert p.delete_rows(b["id"][:3], scanned) == 2
    assert p.count == 8
    assert p.get(int(b["id"][0])) is not None      # the upsert won
    assert p.get(int(b["id"][1])) is None
    # epoch fencing: a stale-epoch write (captured before a compaction
    # renumbered) is rejected wholesale
    epoch = p.epoch
    assert p.compact() > 0
    assert p.epoch > epoch
    assert p.delete_rows(b["id"][3:5], np.array([3, 4]),
                         expect_epoch=epoch) == 0
    fixed = {k: v[3:5].copy() for k, v in b.items()}
    assert p.repair_rows(fixed, np.array([3, 4]), {"t": 3},
                         expect_epoch=epoch) == 0
    assert not p.update_lineage(0, 8, {"t": 3}, expect_epoch=epoch)
    assert p.count == 8                            # nothing misapplied


def test_repair_rows_conditional_on_index():
    p = StoragePartition(0)
    b = batch_of(10, seed=10)
    p.insert(b, upsert=False, lineage={"t": 1})
    # a concurrent ingest upsert supersedes row 0's position
    newer = {k: v[:1].copy() for k, v in b.items()}
    newer["country"] = newer["country"] + 50
    p.insert(newer, upsert=True, lineage={"t": 2})
    fixed = {k: v[:3].copy() for k, v in b.items()}
    fixed["country"] = fixed["country"] + 7
    n = p.repair_rows(fixed, np.arange(3), {"t": 2})
    assert n == 2                                      # row 0 superseded
    pk0 = int(b["id"][0])
    assert int(p.get(pk0)["country"]) == int(b["country"][0]) + 50
    pk1 = int(b["id"][1])
    assert int(p.get(pk1)["country"]) == int(b["country"][1]) + 7
    assert p.count == 10
    # re-applying the same repair is a no-op (exactly-once)
    assert p.repair_rows(fixed, np.arange(3), {"t": 2}) == 0


def test_update_lineage_matches_unit_boundaries(tmp_path):
    p = StoragePartition(0, spill_dir=str(tmp_path), segment_rows=10)
    p.insert(batch_of(10, seed=11), upsert=False, lineage={"t": 1})
    p.insert(batch_of(4, seed=12, start_id=1000), upsert=False,
             lineage={"t": 1})
    assert p.update_lineage(0, 10, {"t": 5})           # the segment
    assert p.update_lineage(10, 4, {"t": 6})           # the chunk
    assert not p.update_lineage(3, 2, {"t": 9})        # no such unit
    assert [lin for _, _, lin in p.lineage_units()] == [{"t": 5}, {"t": 6}]
    # segment lineage durability: throttled to LINEAGE_SYNC_S between
    # flushes, so flush() is the sync point (a crash before it only
    # regresses lineage to older-therefore-stale — safe re-probe)
    p.flush()
    fresh = StoragePartition(0, spill_dir=str(tmp_path)).recover()
    assert [lin for _, _, lin in fresh.lineage_units()][0] == {"t": 5}


# ---------------------------------------------------------------------------
# manifest durability (satellite: fsync'd atomic rename + .bak fallback)
# ---------------------------------------------------------------------------

def test_recover_torn_manifest_falls_back_to_bak(tmp_path):
    """A torn/empty MANIFEST.json (crash mid-replace on a reordering
    filesystem) recovers from the ``.bak`` predecessor: state regresses
    one flush, never silently to empty."""
    p = StoragePartition(0, spill_dir=str(tmp_path), segment_rows=10)
    b1 = batch_of(10, seed=31)
    p.insert(b1, upsert=False, lineage={"t": 1})       # -> manifest v1
    p.insert(batch_of(10, seed=32, start_id=1000), upsert=False,
             lineage={"t": 2})                         # -> v2, v1 = .bak
    man = os.path.join(str(tmp_path), "p0", "MANIFEST.json")
    assert os.path.exists(man + ".bak")
    with open(man, "w"):
        pass                                           # torn: zero bytes
    fresh = StoragePartition(0, spill_dir=str(tmp_path)).recover()
    assert fresh.count == 10                           # the v1 state
    assert fresh.get(int(b1["id"][3])) is not None
    # half-written JSON and non-dict JSON fall back the same way
    for garbage in ('{"format": 2, "segments": 2, "seg_fi', "42"):
        with open(man, "w") as f:
            f.write(garbage)
        again = StoragePartition(0, spill_dir=str(tmp_path)).recover()
        assert again.count == 10


def test_recover_garbage_manifest_without_bak_raises(tmp_path):
    """An unreadable manifest with no usable .bak must raise, not
    silently recover an empty partition (that would drop data)."""
    p = StoragePartition(0, spill_dir=str(tmp_path), segment_rows=10)
    p.insert(batch_of(10, seed=33), upsert=False, lineage={"t": 1})
    man = os.path.join(str(tmp_path), "p0", "MANIFEST.json")
    assert not os.path.exists(man + ".bak")            # first-ever flush
    with open(man, "w") as f:
        f.write("not json{")
    with pytest.raises(RuntimeError, match="MANIFEST"):
        StoragePartition(0, spill_dir=str(tmp_path)).recover()


def test_durable_wal_storage_round_trip(tmp_path):
    """Storage-level exactly-once: a crash between checkpoint and WAL
    truncation makes replay at-least-once; the conditional pk-index
    insert (upsert=False) turns redelivery into a no-op."""
    from repro.core.durability import CheckpointStore, IntakeLog
    from repro.core.records import batch_rows

    wal_dir = os.path.join(str(tmp_path), "intake")
    store_dir = os.path.join(str(tmp_path), "store")
    wal = IntakeLog(wal_dir, fsync="always")
    sj = StorageJob(2, spill_dir=store_dir, segment_rows=40)
    src = SyntheticTweets(seed=41)
    seqs = []
    for i in range(6):
        lines = src.raw_lines(20)
        seqs.append(wal.append_frame((i + 1) * 20, lines))
        sj.write(parse_json_lines(lines))
    sj.flush()
    # checkpoint claims only the first 3 frames; "crash" before truncate
    CheckpointStore(str(tmp_path)).save({"watermark": seqs[2]})
    wal.close()

    fresh = StorageJob(2, spill_dir=store_dir).recover()
    assert fresh.count == 120                          # all flushed rows
    ck = CheckpointStore(str(tmp_path)).load()
    wal2 = IntakeLog(wal_dir, fsync="always")
    try:
        replayed = list(wal2.replay(ck["watermark"]))
        assert [r.seq for r in replayed] == seqs[3:]
        stored = sum(fresh.write(parse_json_lines(r.lines))
                     for r in replayed)
    finally:
        wal2.close()
    assert stored == 0                                 # pure redelivery
    assert fresh.count == 120
    assert sum(batch_rows(p.read_rows(0, p.count))
               for p in fresh.partitions if p.count) == 120


# ---------------------------------------------------------------------------
# leveled segment merging (tentpole: merge_segments + manifest format 3)
# ---------------------------------------------------------------------------

def test_merge_segments_levels_lineage_sort_and_renumbering(tmp_path):
    """K adjacent small segments merge into ONE at max(level)+1: dead
    versions drop, the union re-sorts on sort_key, lineage min-merges,
    and every surviving pk still resolves through the renumbered index."""
    p = StoragePartition(0, spill_dir=str(tmp_path), segment_rows=10,
                         sort_key="country")
    batches = [batch_of(10, seed=s, start_id=s * 1000)
               for s in range(1, 5)]
    for t, b in enumerate(batches, start=1):
        p.insert(b, upsert=True, lineage={"t": t})     # -> 4 segments
    churn = {k: v.copy() for k, v in batches[1].items()}
    churn["country"] = churn["country"] + 100
    p.insert(churn, upsert=True, lineage={"t": 5})     # seg 1 fully dead
    p.flush()
    assert p.dead_rows == 10 and len(p.segment_stats()) == 5
    epoch = p.epoch

    n, dropped = p.merge_segments(0, 4)
    assert (n, dropped) == (40, 10)
    assert p.epoch > epoch                 # merges ALWAYS bump the epoch
    stats = p.segment_stats()
    assert stats == [(30, 0, 1), (10, 0, 0)]
    assert p.level_histogram() == {0: 1, 1: 1}
    assert p.count == 40 and p.rows_total == 40 and p.dead_rows == 0
    with p._lock:
        assert p._seg_lineage[0] == {"t": 1}           # oldest wins
    # the merged segment is clustered on the sort key
    snap = p.snapshot_view()
    try:
        cols = snap.units[0].read(("id", "country"))
        assert cols["id"].shape[0] == 30
        assert (np.diff(cols["country"]) >= 0).all()
        assert snap.live_mask(cols["id"], 0).all()
    finally:
        snap.release()
    # point reads: untouched batches keep originals, churned one the upsert
    for b in (batches[0], batches[2], batches[3]):
        for i in range(0, 10, 3):
            assert int(p.get(int(b["id"][i]))["country"]) == \
                int(b["country"][i])
    for i in range(0, 10, 3):
        assert int(p.get(int(batches[1]["id"][i]))["country"]) == \
            int(batches[1]["country"][i]) + 100


def test_merge_manifest_format3_round_trip(tmp_path):
    p = StoragePartition(0, spill_dir=str(tmp_path), segment_rows=10)
    for s in range(1, 5):
        p.insert(batch_of(10, seed=s, start_id=s * 1000), upsert=False,
                 lineage={"t": s})
    p.merge_segments(0, 3)
    man = os.path.join(str(tmp_path), "p0", "MANIFEST.json")
    with open(man) as f:
        doc = json.load(f)
    assert doc["format"] == 3
    assert doc["levels"] == [1, 0]
    fresh = StoragePartition(0, spill_dir=str(tmp_path)).recover()
    assert fresh.segment_stats() == p.segment_stats()
    assert fresh.level_histogram() == {0: 1, 1: 1}
    assert fresh.count == 40
    # and the recovered layout merges again, deepening the level
    fresh.merge_segments(0, 2)
    assert fresh.segment_stats() == [(40, 0, 2)]


def test_format2_manifest_recovers_as_level0(tmp_path):
    """Pre-level manifests (format 2: lineage + zone maps, no levels)
    recover every segment at level 0 — merge-eligible, never rejected."""
    p = StoragePartition(0, spill_dir=str(tmp_path), segment_rows=10)
    for s in range(1, 4):
        p.insert(batch_of(10, seed=s, start_id=s * 1000), upsert=False,
                 lineage={"t": s})
    p.merge_segments(0, 2)                             # a level-1 segment
    man = os.path.join(str(tmp_path), "p0", "MANIFEST.json")
    with open(man) as f:
        doc = json.load(f)
    del doc["levels"]
    doc["format"] = 2
    with open(man, "w") as f:
        json.dump(doc, f)
    fresh = StoragePartition(0, spill_dir=str(tmp_path)).recover()
    assert fresh.count == 30
    assert [lv for _r, _d, lv in fresh.segment_stats()] == [0, 0]
    assert fresh.level_histogram() == {0: 2}


def test_merge_rebuilds_zone_maps_from_legacy_manifest(tmp_path):
    """Satellite regression: segments recovered from a zone-map-less
    (format-1-era) manifest are never pruned — but merging them rebuilds
    zone maps unconditionally, so aged legacy data regains pruning."""
    p = StoragePartition(0, spill_dir=str(tmp_path), segment_rows=10)
    b1 = batch_of(10, seed=51)
    b2 = batch_of(10, seed=52, start_id=1000)
    p.insert(b1, upsert=False, lineage={"t": 1})
    p.insert(b2, upsert=False, lineage={"t": 2})
    man = os.path.join(str(tmp_path), "p0", "MANIFEST.json")
    with open(man) as f:
        doc = json.load(f)
    del doc["zone_maps"]
    del doc["lineage"]
    with open(man, "w") as f:
        json.dump(doc, f)
    legacy = StoragePartition(0, spill_dir=str(tmp_path)).recover()
    with legacy._lock:
        assert legacy._seg_zmaps == [{}, {}]           # unprunable
    legacy.merge_segments(0, 2)
    snap = legacy.snapshot_view()
    try:
        zm = snap.units[0].zone_map
        assert zm and zm["id"] == (
            int(min(b1["id"].min(), b2["id"].min())),
            int(max(b1["id"].max(), b2["id"].max())))
    finally:
        snap.release()


def test_pinned_snapshot_survives_live_merge(tmp_path):
    """Snapshot isolation across a merge: the replaced segment files stay
    on disk (and readable) while any pin is held, and are GC'd only after
    the last release."""
    p = StoragePartition(0, spill_dir=str(tmp_path), segment_rows=10)
    batches = [batch_of(10, seed=s, start_id=s * 1000)
               for s in range(61, 64)]
    for b in batches:
        p.insert(b, upsert=False, lineage={"t": 1})
    snap = p.snapshot_view()
    old_paths = [u.path for u in snap.units]
    assert len(old_paths) == 3 and all(old_paths)

    n, dropped = p.merge_segments(0, 3)
    assert (n, dropped) == (30, 0)                     # pure reshaping
    for path in old_paths:                             # pinned: not GC'd
        assert os.path.exists(path)
    seen = []
    for u in snap.units:                               # still readable
        cols = u.read(("id",))
        assert snap.live_mask(cols["id"], u.base).all()
        seen.extend(int(x) for x in cols["id"])
    assert sorted(seen) == sorted(
        int(x) for b in batches for x in b["id"])
    snap.release()
    for path in old_paths:                             # unpinned: gone
        assert not os.path.exists(path)
    fresh = p.snapshot_view()
    try:
        assert [u.rows for u in fresh.units] == [30]
    finally:
        fresh.release()


def test_merge_fully_dead_run_drops_segments(tmp_path):
    p = StoragePartition(0, spill_dir=str(tmp_path), segment_rows=10)
    b1 = batch_of(10, seed=71)
    b2 = batch_of(10, seed=72, start_id=1000)
    p.insert(b1, upsert=True, lineage={"t": 1})
    p.insert(b2, upsert=True, lineage={"t": 2})
    for b in (b1, b2):                       # supersede everything
        again = {k: v.copy() for k, v in b.items()}
        again["country"] = again["country"] + 9
        p.insert(again, upsert=True, lineage={"t": 3})
    p.flush()
    assert p.dead_rows == 20
    n, dropped = p.merge_segments(0, 2)
    assert (n, dropped) == (20, 20)          # no empty segment written
    assert [lv for _r, _d, lv in p.segment_stats()] == [0, 0]
    assert p.count == 20 and p.dead_rows == 0
    fresh = StoragePartition(0, spill_dir=str(tmp_path)).recover()
    assert fresh.count == 20
    assert int(fresh.get(int(b1["id"][2]))["country"]) == \
        int(b1["country"][2]) + 9


def test_merge_epoch_fences_stale_conditional_writes(tmp_path):
    p = StoragePartition(0, spill_dir=str(tmp_path), segment_rows=10)
    b = batch_of(20, seed=81)
    p.insert({k: v[:10] for k, v in b.items()}, upsert=True,
             lineage={"t": 1})
    p.insert({k: v[10:] for k, v in b.items()}, upsert=True,
             lineage={"t": 1})                         # -> 2 segments
    epoch = p.epoch
    p.merge_segments(0, 2)
    # conditional writes captured before the merge renumbered: rejected
    fixed = {k: v[:3].copy() for k, v in b.items()}
    assert p.repair_rows(fixed, np.arange(3), {"t": 2},
                         expect_epoch=epoch) == 0
    assert p.delete_rows(b["id"][:3], np.arange(3),
                         expect_epoch=epoch) == 0
    assert not p.update_lineage(0, 20, {"t": 2}, expect_epoch=epoch)
    assert p.count == 20


def test_merge_flushes_buffered_supersessions_before_dropping(tmp_path):
    """repair_rows re-appends the repaired version at the tail — into a
    BUFFERED chunk.  A merge must not physically drop the superseded
    (flushed, durable) version while its successor is still volatile:
    flush-then-drop, or a crash right after the merge loses the row
    (its WAL frame is long truncated).  Pinned by recover()ing from the
    post-merge on-disk state, which is exactly what a crash would see."""
    p = StoragePartition(0, spill_dir=str(tmp_path), segment_rows=10)
    b = batch_of(20, seed=91)
    p.insert({k: v[:10] for k, v in b.items()}, upsert=True,
             lineage={"t": 1})
    p.insert({k: v[10:] for k, v in b.items()}, upsert=True,
             lineage={"t": 1})                         # -> 2 segments
    # repair 3 rows of segment 0: newer versions land in a buffered
    # chunk (under the flush threshold), their flushed originals go dead
    fixed = {k: v[:3].copy() for k, v in b.items()}
    fixed["country"] = fixed["country"] + 7
    assert p.repair_rows(fixed, np.arange(3), {"t": 2},
                         expect_epoch=p.epoch) == 3
    assert p._rows_buffered == 3
    rows, dropped = p.merge_segments(0, 2)
    assert dropped == 3                  # the superseded originals
    assert p._rows_buffered == 0         # chunk flushed INSIDE the merge
    # crash now: a fresh partition over the same dir must see every row,
    # with the repaired values (the successor was made durable first)
    r = StoragePartition(0, spill_dir=str(tmp_path)).recover()
    assert r.count == 20
    for i in range(3):
        assert int(r.get(int(b["id"][i]))["country"]) == \
            int(b["country"][i]) + 7


def test_fully_dead_segment_is_deleted_not_left_empty(tmp_path):
    """A segment whose every row is superseded must be REMOVED by
    compaction, not rewritten as a 0-row segment: an empty unit would
    surface from lineage_units() as permanently-stale work that
    read_rows() can never return, wedging repair convergence."""
    p = StoragePartition(0, spill_dir=str(tmp_path), segment_rows=10)
    b = batch_of(20, seed=17)
    p.insert({k: v[:10] for k, v in b.items()}, upsert=True,
             lineage={"t": 1})
    p.insert({k: v[10:] for k, v in b.items()}, upsert=True,
             lineage={"t": 1})
    # supersede EVERY row of segment 0; the new versions fill a third
    # segment, so segment 0 is 100% dead and flushed-durable everywhere
    p.insert({k: v[:10] for k, v in b.items()}, upsert=True,
             lineage={"t": 2})
    assert len(p._seg_rows) == 3 and p._seg_dead[0] == 10
    assert p.compact() == 10
    assert p._seg_rows == [10, 10]       # entry deleted, not emptied
    assert all(r > 0 for _, r, _ in p.lineage_units())
    assert p.count == 20
    r = StoragePartition(0, spill_dir=str(tmp_path)).recover()
    assert r.count == 20 and r._seg_rows == [10, 10]


def test_merge_segments_rejects_bad_ranges():
    p = StoragePartition(0)
    with pytest.raises(IndexError):
        p.merge_segments(0, 2)                         # nothing flushed
    with pytest.raises(IndexError):
        p.merge_segments(0, 1)                         # count < 2


def test_find_merge_run_policy():
    from repro.core.compaction import find_merge_run
    seg = lambda rows, level=0: (rows, 0, level)       # noqa: E731
    # disabled / nothing small enough
    assert find_merge_run([seg(5)] * 8, 4, 0) is None
    assert find_merge_run([seg(100)] * 8, 4, 50) is None
    # a run of exactly fanin merges; longer runs cap at fanin inputs
    assert find_merge_run([seg(5)] * 4, 4, 50) == (0, 4, 20)
    assert find_merge_run([seg(5)] * 7, 4, 50) == (0, 4, 20)
    # graduated segments break runs; a too-short run is skipped whole
    stats = [seg(5), seg(5), seg(100, 1), seg(5), seg(5), seg(5)]
    assert find_merge_run(stats, 3, 50) == (3, 3, 15)
    # min_run relaxes the trigger but never below 2 inputs
    assert find_merge_run([seg(5), seg(5)], 4, 50, min_run=2) == (0, 2, 10)
    assert find_merge_run([seg(5)], 4, 50, min_run=1) is None
    assert find_merge_run([seg(100), seg(5)], 4, 50, min_run=1) is None


def test_compaction_job_schedules_merges(tmp_path):
    from repro.core import CompactionJob, CompactionSpec
    sj = StorageJob(1, spill_dir=str(tmp_path), segment_rows=10)
    for s in range(8):
        sj.write(batch_of(10, seed=s + 1, start_id=s * 1000))
    sj.flush()
    assert sj.segment_count == 8
    # level_target_rows=0 (default): merge_now is a no-op
    off = CompactionJob(sj, CompactionSpec())
    assert off.merge_now() == 0
    assert sj.segment_count == 8
    job = CompactionJob(sj, CompactionSpec(merge_fanin=4,
                                           level_target_rows=35))
    job.step(force=True)
    # two fanin-sized merges; the level-1 outputs (40 rows) graduated
    assert sj.segment_count == 2
    assert sj.level_histogram() == {1: 2}
    assert job.stats.merges == 2
    assert job.stats.segments_merged == 8
    assert job.stats.rows_merged == 80
    assert job.stats.rows_rewritten == 80              # nothing dead
    job.step(force=True)                               # converged
    assert job.stats.merges == 2
    assert sj.count == 80


# ---------------------------------------------------------------------------
# feedlint R3 fix: get() must not decompress a segment under the lock
# ---------------------------------------------------------------------------

def test_get_reads_segments_outside_the_partition_lock(tmp_path,
                                                       monkeypatch):
    """Regression for the feedlint R3 finding: get() used to hold
    ``_lock`` across ``np.load``.  Now it resolves the row and takes a
    pin under the lock, then decompresses outside it — so a concurrent
    insert/flush can never stall behind segment I/O, and the pin keeps
    the file alive if compaction swaps it mid-read."""
    import repro.core.storage as storage_mod

    p = StoragePartition(0, spill_dir=str(tmp_path), segment_rows=8)
    b = batch_of(16, seed=3)
    p.insert(b, upsert=False, lineage={"t": 1})
    p.flush()                                   # -> two durable segments

    real_load = np.load
    probes = []

    def probing_load(path, *a, **k):
        free = p._lock.acquire(blocking=False)  # held => get() regressed
        if free:
            p._lock.release()
        probes.append(free)
        return real_load(path, *a, **k)

    monkeypatch.setattr(storage_mod.np, "load", probing_load)
    for i in (0, 9):                            # one row per segment
        pk = int(b["id"][i])
        row = p.get(pk)
        assert row is not None and int(row["id"]) == pk
        assert int(row["country"]) == int(b["country"][i])
    assert probes and all(probes)               # loads saw the lock free
    assert p._pins == 0                         # every pin released
    assert p.get(10**9) is None                 # miss path unchanged
