"""End-to-end tests of the decoupled ingestion pipeline (§6, §7): the
three-job architecture, partition holders, drain protocol, predeploy cache,
baselines, fault tolerance, work stealing, elasticity, and storage
idempotence."""

import socket
import threading
import time

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (FeedConfig, FeedManager, PartitionHolder,
                        RefStore, StopRecord, StorageJob, SyntheticAdapter,
                        pipeline)
from repro.core.enrich import queries as Q
from repro.core.records import SyntheticTweets, parse_json_lines


def make_manager(scale=0.002):
    store = RefStore()
    Q.make_reference_tables(store, scale=scale, seed=7)
    return FeedManager(store)


def submit(mgr, name, adapter, udf=Q.Q1, batch=50, partitions=2, **opts):
    """Plan-API equivalent of the old one-UDF FeedConfig shim feeds."""
    p = (pipeline(adapter, name).parse(batch_size=batch)
         .options(num_partitions=partitions, **opts))
    if udf is not None:
        p.enrich(udf)
    return mgr.submit(p.store())


# ---------------------------------------------------------------------------
# partition holders
# ---------------------------------------------------------------------------

def test_holder_fifo_and_drain():
    h = PartitionHolder(("t", 0), capacity=4)
    for i in range(3):
        h.push(i)
    h.close()
    assert [h.pull() for _ in range(3)] == [0, 1, 2]
    assert isinstance(h.pull(), StopRecord)
    assert isinstance(h.pull(), StopRecord)   # idempotent for all consumers


def test_holder_backpressure():
    h = PartitionHolder(("t", 1), capacity=2)
    assert h.push(1, timeout=0.05)
    assert h.push(2, timeout=0.05)
    assert not h.push(3, timeout=0.05)        # bounded: push times out
    h.pull()
    assert h.push(3, timeout=0.05)


def test_holder_steal_skips_stop():
    h = PartitionHolder(("t", 2), capacity=8)
    h.push("a")
    h.push("b")
    h.close()
    assert h.steal() == "b"                   # newest-first, not the STOP
    assert h.steal() == "a"
    assert h.steal() is None


# ---------------------------------------------------------------------------
# new-framework end-to-end
# ---------------------------------------------------------------------------

def test_feed_end_to_end_enriched_and_complete():
    mgr = make_manager()
    # coalesce_rows=0: this test does exact invocation/compile accounting,
    # which the (default-on) backlog coalescer would legitimately change
    h = submit(mgr, "e2e", SyntheticAdapter(total=1000, frame_size=100,
                                            seed=3),
               batch=100, coalesce_rows=0)
    stats = h.join(timeout=120)
    assert stats.records_in == 1000
    assert stats.stored == 1000
    assert h.storage.count == 1000
    assert stats.computing.invocations == 10
    # predeployed: one compile for q1-apply, arbitrarily many invocations
    assert stats.predeploy["compiles"] <= 2
    assert stats.computing.records == 1000
    # spot-check enrichment against the reference table
    arrays = mgr.refstore["safety_levels"].snapshot().arrays
    table = {int(k): int(v) for k, v in
             zip(arrays["key"], arrays["safety_level"])}
    src = SyntheticTweets(seed=3)
    raw = parse_json_lines(src.raw_lines(5))
    for i in range(5):
        row = h.storage.get(int(raw["id"][i]))
        assert row is not None
        assert int(row["safety_level"]) == table.get(
            int(raw["country"][i]), -1)


def test_feed_partial_last_batch_padded():
    mgr = make_manager()
    h = submit(mgr, "partial", SyntheticAdapter(total=150, frame_size=64),
               batch=64, partitions=1, coalesce_rows=0)
    stats = h.join(timeout=60)
    assert stats.stored == 150                # 64+64+22 (padded, not lost)
    assert stats.predeploy["compiles"] <= 2   # one shape -> one executable


def test_feed_without_udf_pure_ingestion():
    mgr = make_manager()
    h = submit(mgr, "pure", SyntheticAdapter(total=500, frame_size=50),
               udf=None)
    stats = h.join(timeout=60)
    assert stats.stored == 500
    assert stats.predeploy["compiles"] == 0


@pytest.mark.parametrize("framework", ["current", "balanced"])
def test_coupled_baselines_store_everything(framework):
    mgr = make_manager()
    cfg = FeedConfig(name=f"b-{framework}", udf=Q.Q2, batch_size=50,
                     num_partitions=2, framework=framework)
    h = mgr.start(cfg, SyntheticAdapter(total=300, frame_size=50))
    stats = h.join(timeout=60)
    assert stats.stored == 300
    # Model 3 under the hood: state built once per worker, then reused
    assert stats.computing.state_builds <= cfg.num_partitions


def test_insert_baseline_recompiles_every_batch():
    mgr = make_manager()
    cfg = FeedConfig(name="ins", udf=Q.Q1, batch_size=50,
                     framework="insert")
    h = mgr.start(cfg, SyntheticAdapter(total=200, frame_size=50))
    stats = h.join(timeout=120)
    assert stats.stored == 200
    # approach 1 pays compilation per statement (the paper's §3 bottleneck)
    assert h.runners[0] is not None


# ---------------------------------------------------------------------------
# fault tolerance / stealing / elasticity
# ---------------------------------------------------------------------------

def test_fault_injection_retry_exactly_once():
    mgr = make_manager()
    failed = set()

    def hook(inv):
        if inv == 3 and 3 not in failed:
            failed.add(3)
            return True
        return False

    # coalesce_rows=0: the hook targets a specific invocation ordinal
    h = submit(mgr, "fault", SyntheticAdapter(total=500, frame_size=50),
               fault_hook=hook, coalesce_rows=0)
    stats = h.join(timeout=60)
    assert stats.retries == 1
    assert stats.stored == 500                 # nothing lost, nothing doubled
    assert h.storage.count == 500


def test_fault_exhausted_retries_surfaces():
    mgr = make_manager()
    h = submit(mgr, "fatal", SyntheticAdapter(total=100, frame_size=50),
               partitions=1, max_retries=1, retry_backoff_s=0.01,
               fault_hook=lambda inv: True)
    with pytest.raises(RuntimeError, match="injected fault"):
        h.join(timeout=60)


def test_work_stealing_engages_for_imbalanced_partitions():
    mgr = make_manager()
    # many partitions, tiny frames: some holders will back up; idle workers
    # must steal rather than spin
    h = submit(mgr, "steal", SyntheticAdapter(total=2000, frame_size=20),
               batch=20, partitions=4, holder_capacity=32)
    stats = h.join(timeout=120)
    assert stats.stored == 2000


def test_elastic_scale_up_mid_feed():
    mgr = make_manager()
    adapter = SyntheticAdapter(total=1500, frame_size=25, rate=5000.0)
    h = submit(mgr, "elastic", adapter, batch=25, partitions=1)
    time.sleep(0.1)
    h.scale_up(2)                              # 1 -> 3 computing partitions
    stats = h.join(timeout=120)
    assert len(h.holders) == 3
    assert stats.stored == 1500
    # the round-robin partitioner actually targeted the new holders
    assert sum(hh.pulled > 0 for hh in h.holders) >= 2


def test_graceful_stop_drains_in_flight():
    mgr = make_manager()
    adapter = SyntheticAdapter(total=1_000_000, frame_size=50, rate=20000.0)
    h = submit(mgr, "stop", adapter)
    time.sleep(0.3)
    h.stop()
    stats = h.join(timeout=60)
    assert 0 < stats.stored <= 1_000_000
    assert stats.stored == stats.records_in    # drained, none lost


# ---------------------------------------------------------------------------
# storage
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(1, 5), st.integers(1, 200))
def test_storage_idempotent_under_duplicate_delivery(nparts, nrows):
    sj = StorageJob(nparts)
    b = parse_json_lines(SyntheticTweets(seed=1).raw_lines(nrows))
    sj.write(b)
    sj.write(b)                                # duplicate delivery (retry)
    assert sj.count == nrows


def test_storage_spill_and_read_back(tmp_path):
    sj = StorageJob(2, spill_dir=str(tmp_path))
    b = parse_json_lines(SyntheticTweets(seed=2).raw_lines(100))
    sj.write(b)
    sj.flush()
    row = sj.get(int(b["id"][7]))
    assert row is not None
    assert int(row["country"]) == int(b["country"][7])


def test_socket_adapter_feed():
    from repro.core import SocketAdapter
    mgr = make_manager()
    adapter = SocketAdapter("127.0.0.1", 0, frame_size=20)
    host, port = adapter.address
    h = submit(mgr, "sock", adapter, udf=Q.UDF1, batch=20, partitions=1)

    def client():
        lines = SyntheticTweets(seed=9).raw_lines(100)
        with socket.create_connection((host, port)) as c:
            c.sendall(b"\n".join(lines) + b"\n")

    t = threading.Thread(target=client)
    t.start()
    t.join()
    stats = h.join(timeout=60)
    assert stats.stored == 100
