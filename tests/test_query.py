"""Analytical query subsystem (core/query.py): predicate algebra +
zone-map interval analysis, snapshot-consistent scans with latest-wins
over superseded/deleted versions, kernel-routed group-by aggregation, and
segment compaction — all pinned against a NAIVE python/numpy full-scan
reference on the same snapshot (the acceptance criterion: bitwise
identical, with and without pruning/compaction, and under concurrent
ingestion + repair + compaction).

Deliberately hypothesis-free: runs in the minimal-install CI job.
"""

import threading
import time

import numpy as np
import pytest

from repro.core import (CompactionJob, CompactionSpec, FeedManager,
                        PlanError, QueryError, RefStore, RepairSpec,
                        StorageJob, StoreSnapshot, SyntheticAdapter, agg,
                        col, pipeline)
from repro.core.enrich import queries as Q
from repro.core.records import SyntheticTweets, parse_json_lines


def batch_of(n, seed=1, start_id=0, extra=None):
    b = parse_json_lines(
        SyntheticTweets(seed=seed, start_id=start_id).raw_lines(n))
    for k, fn in (extra or {}).items():
        b[k] = fn(b)
    return b


SAFETY = {"safety_level": lambda b: (b["country"] % 5).astype(np.int32)}


def make_store(tmp_path=None, nparts=2, segment_rows=40, upsert=True,
               **kw):
    return StorageJob(nparts, spill_dir=str(tmp_path) if tmp_path else None,
                      upsert=upsert, segment_rows=segment_rows, **kw)


# ---------------------------------------------------------------------------
# naive full-scan reference (python loops on the same snapshot)
# ---------------------------------------------------------------------------

def naive_rows(snap: StoreSnapshot, keep=None):
    """Live rows of a snapshot, in scan order, as a list of dicts —
    independent of the query executor (live_mask is the shared latest-wins
    primitive; everything else is python)."""
    rows = []
    for ps in snap.parts:
        for u in ps.units:
            cols = u.read(None)
            if u.rows == 0:
                continue
            live = ps.live_mask(cols["id"], u.base)
            for i in range(u.rows):
                if not live[i]:
                    continue
                r = {k: cols[k][i] for k in cols}
                if keep is None or keep(r):
                    rows.append(r)
    return rows


def naive_group(rows, key, value=None, topk=None):
    """Per-key count/sum/top-k with the documented semantics: keys
    ascending; top-k by value desc, ties by scan order."""
    keys = sorted({int(r[key]) for r in rows})
    n = {k: 0 for k in keys}
    s = {k: 0 for k in keys}
    cand = {k: [] for k in keys}
    for pos, r in enumerate(rows):
        k = int(r[key])
        n[k] += 1
        if value is not None:
            s[k] += int(r[value])
        if topk is not None:
            cand[k].append((int(r[topk[0]]), pos, int(r[topk[2]])))
    out = {"keys": keys, "count": [n[k] for k in keys],
           "sum": [s[k] for k in keys]}
    if topk is not None:
        kk = topk[1]
        tops = []
        for k in keys:
            sel = sorted(range(len(cand[k])),
                         key=lambda i: (-cand[k][i][0], cand[k][i][1]))[:kk]
            tops.append([cand[k][i][2] for i in sel]
                        + [-1] * (kk - len(sel)))
        out["topk"] = tops
    return out


def fill_store(sj, total=400, batch=80, seed=3, lineage=None):
    src = SyntheticTweets(seed=seed)
    for f in src.batches(total, batch):
        b = parse_json_lines(f)
        b["safety_level"] = (b["country"] % 5).astype(np.int32)
        sj.write(b, lineage=lineage or {"t": 1})
    return sj


# ---------------------------------------------------------------------------
# predicate algebra + zone maps
# ---------------------------------------------------------------------------

def test_predicate_masks_and_zone_map_intervals():
    cols = {"x": np.array([1, 5, 9]), "y": np.array([2.0, 2.0, 7.0])}
    p = (col("x") >= 5) & (col("y") < 7)
    np.testing.assert_array_equal(p.mask(cols), [False, True, False])
    assert p.columns == frozenset({"x", "y"})
    zm = {"x": (0, 4), "y": (0.0, 10.0)}
    assert not p.maybe(zm)                       # x can never reach 5
    assert p.maybe({"x": (5, 9), "y": (0.0, 3.0)})
    assert ((col("x") == 3) | (col("x") == 99)).maybe({"x": (0, 4)})
    assert not ((col("x") == 5) | (col("x") == 99)).maybe({"x": (0, 4)})
    assert not col("x").isin([7, 8]).maybe({"x": (0, 4)})
    assert col("x").isin([3, 8]).maybe({"x": (0, 4)})
    assert not col("x").between(10, 20).maybe({"x": (0, 4)})
    # unknown columns / negation never prune (conservative)
    assert (col("z") == 1).maybe(zm)
    assert (~(col("x") == 1)).maybe({"x": (1, 1)})
    np.testing.assert_array_equal((~(col("x") == 5)).mask(cols),
                                  [True, False, True])
    # != prunes only the provably-constant case
    assert not (col("x") != 2).maybe({"x": (2, 2)})
    assert (col("x") != 2).maybe({"x": (2, 3)})


def test_query_builder_rejects_bad_shapes():
    sj = make_store()
    with pytest.raises(QueryError, match="at least one aggregate"):
        sj.query().group_by("country").execute()
    with pytest.raises(QueryError, match="mutually exclusive"):
        sj.query().select("id").agg(n=agg.count()).execute()
    with pytest.raises(QueryError, match="sum/count/mean/topk"):
        sj.query().agg(n=42)
    with pytest.raises(QueryError, match="not a predicate"):
        sj.query().where(7)
    with pytest.raises(QueryError):
        agg.topk("x", k=0)


# ---------------------------------------------------------------------------
# scans: naive equality, latest-wins, pruning
# ---------------------------------------------------------------------------

def test_scan_matches_naive_with_and_without_pruning(tmp_path):
    sj = fill_store(make_store(tmp_path))
    sj.flush()
    pred = (col("safety_level") >= 3) & (col("id") < 250)
    with sj.snapshot() as snap:
        want = naive_rows(snap, lambda r: r["safety_level"] >= 3
                          and r["id"] < 250)
        got_on = sj.query().where(pred).select("id", "safety_level") \
            .execute(snapshot=snap)
        got_off = sj.query().where(pred).select("id", "safety_level") \
            .execute(prune=False, snapshot=snap)
    # scan order == naive order -> arrays are bitwise identical
    np.testing.assert_array_equal(got_on["id"],
                                  np.array([r["id"] for r in want]))
    np.testing.assert_array_equal(
        got_on["safety_level"],
        np.array([r["safety_level"] for r in want]))
    for k in got_on:
        np.testing.assert_array_equal(got_on[k], got_off[k])
    # the id range predicate provably skipped flushed segments, no-prune
    # scanned everything
    assert got_on.stats.segments_pruned > 0
    assert got_off.stats.segments_pruned == 0
    assert got_off.stats.rows_scanned > got_on.stats.rows_scanned


def test_latest_wins_over_upsert_churn_and_callable_predicate():
    sj = fill_store(make_store(segment_rows=10_000))  # in-memory chunks
    b = batch_of(60, seed=3, extra=SAFETY)            # re-write ids w/ new
    b["safety_level"] = np.full(60, 9, np.int32)      # safety level
    sj.write(b, lineage={"t": 2})
    with sj.snapshot() as snap:
        want = naive_rows(snap, lambda r: r["safety_level"] == 9)
        got = sj.query().where(lambda c: c["safety_level"] == 9) \
            .select("id").execute(snapshot=snap)
    assert sorted(got["id"].tolist()) == \
        sorted(int(r["id"]) for r in want)
    assert got.rows == 60                             # exactly the rewrites


def test_deleted_rows_drop_out_of_queries():
    sj = fill_store(make_store(segment_rows=10_000), total=100)
    p0 = sj.partitions[0]
    with p0._lock:
        ids = p0._index._pks[:5].copy()
        rows = p0._index._rows[:5].copy()
    assert p0.delete_rows(ids, rows) == 5
    res = sj.query().select("id").execute()
    assert res.rows == sj.count == 95
    assert not np.isin(ids, res["id"]).any()
    # reclaim, then identical again
    sj.compact()
    assert sj.dead_rows == 0
    res2 = sj.query().select("id").execute()
    assert sorted(res2["id"].tolist()) == sorted(res["id"].tolist())


# ---------------------------------------------------------------------------
# group-by aggregation vs naive (count / sum / mean / topk, tie-breaks)
# ---------------------------------------------------------------------------

def test_group_agg_bitwise_matches_naive(tmp_path):
    sj = fill_store(make_store(tmp_path, segment_rows=64), total=500,
                    seed=7)
    sj.flush()
    with sj.snapshot() as snap:
        rows = naive_rows(snap, lambda r: r["safety_level"] >= 1)
        want = naive_group(rows, "country", value="created_at",
                           topk=("safety_level", 3, "id"))
        got = (sj.query().where(col("safety_level") >= 1)
               .group_by("country")
               .agg(n=agg.count(), total=agg.sum("created_at"),
                    m=agg.mean("created_at"),
                    top=agg.topk("safety_level", k=3, payload="id"))
               .execute(snapshot=snap))
    assert got["country"].tolist() == want["keys"]
    assert got["n"].tolist() == want["count"]
    assert got["total"].tolist() == want["sum"]       # int64-exact
    assert got["total"].dtype == np.int64
    np.testing.assert_allclose(
        got["m"], np.array(want["sum"]) / np.array(want["count"]))
    assert got["top"].tolist() == want["topk"]        # ties: scan order
    assert got.stats.agg_invocations > 0


def test_batched_agg_identical_to_eager_and_naive(tmp_path):
    """Tentpole: the default batched path concatenates surviving units
    into ONE dispatch per aggregate — bitwise identical to the eager
    per-unit path and the naive reference, at a fraction of the
    invocations."""
    sj = fill_store(make_store(tmp_path, segment_rows=32), total=400,
                    seed=11)
    b = batch_of(100, seed=11, extra=SAFETY)          # upsert churn
    b["safety_level"] = (b["country"] % 5).astype(np.int32)
    sj.write(b, lineage={"t": 2})
    sj.flush()
    q = (sj.query().where(col("safety_level") >= 1)
         .group_by("country")
         .agg(n=agg.count(), total=agg.sum("created_at"),
              m=agg.mean("created_at"),
              top=agg.topk("safety_level", k=3, payload="id")))
    with sj.snapshot() as snap:
        rows = naive_rows(snap, lambda r: r["safety_level"] >= 1)
        want = naive_group(rows, "country", value="created_at",
                           topk=("safety_level", 3, "id"))
        got = q.execute(snapshot=snap)
        eager = q.execute(snapshot=snap, batched=False)
    assert got["country"].tolist() == want["keys"]
    assert got["n"].tolist() == want["count"]
    assert got["total"].tolist() == want["sum"]
    assert got["top"].tolist() == want["topk"]        # ties: scan order
    for k in got:
        np.testing.assert_array_equal(got[k], eager[k])  # incl. mean
    # one consume for the whole query vs one per surviving unit: the
    # per-consume dispatches collapse by exactly the unit fan-in (the
    # final topk candidate-merge dispatch is shared by both paths)
    assert got.stats.agg_batched_units > 1
    assert eager.stats.agg_invocations == \
        (got.stats.agg_invocations - 1) * got.stats.agg_batched_units + 1
    assert eager.stats.agg_batched_units == 0


def test_batched_agg_bare_count_without_group(tmp_path):
    sj = fill_store(make_store(tmp_path, segment_rows=32), total=200)
    sj.flush()
    got = sj.query().agg(n=agg.count()).execute()
    assert got["n"].tolist() == [200]
    assert got.stats.agg_batched_units > 1


def test_agg_results_stable_across_leveled_merge(tmp_path):
    """Merging K small segments into one level-1 segment must not change
    any query answer — and the batched path collapses with it."""
    sj = make_store(tmp_path, nparts=1, segment_rows=32,
                    sort_key="country")
    fill_store(sj, total=400, seed=13)
    b = batch_of(100, seed=13, extra=SAFETY)          # churn -> dead rows
    sj.write(b, lineage={"t": 2})
    sj.flush()
    q = (sj.query().where(col("safety_level") >= 1)
         .group_by("country")
         .agg(n=agg.count(), total=agg.sum("created_at"),
              top=agg.topk("safety_level", k=2, payload="id")))
    before = q.execute()
    segs_before = sj.segment_count
    job = CompactionJob(sj, CompactionSpec(merge_fanin=8,
                                           level_target_rows=100_000))
    assert job.merge_now() > 0                        # churn reclaimed
    assert sj.segment_count < segs_before
    assert max(sj.level_histogram()) >= 1
    after = q.execute()
    for k in before:
        np.testing.assert_array_equal(before[k], after[k])
    assert after.stats.units < before.stats.units
    assert after.stats.rows_scanned < before.stats.rows_scanned


def test_query_stats_report_kernel_vs_fallback_dispatches(tmp_path):
    """Satellite: int64 aggregation must be VISIBLE as the explicit
    wide-dtype XLA fallback in QueryStats, not silently slow."""
    sj = fill_store(make_store(tmp_path, segment_rows=64), total=200)
    sj.flush()
    got = (sj.query().group_by("country")
           .agg(total=agg.sum("created_at"))      # created_at is int64
           .execute())
    assert got.stats.agg_64bit_fallbacks >= 1
    assert got.stats.agg_fallback_dispatches >= \
        got.stats.agg_64bit_fallbacks
    total = got.stats.agg_kernel_dispatches + \
        got.stats.agg_fallback_dispatches
    assert total >= 1
    # an int32 count-only query never touches the 64-bit path
    cnt = sj.query().group_by("country").agg(n=agg.count()).execute()
    assert cnt.stats.agg_64bit_fallbacks == 0


def test_global_agg_without_group_by():
    sj = fill_store(make_store(), total=200)
    with sj.snapshot() as snap:
        rows = naive_rows(snap)
        got = sj.query().agg(n=agg.count(),
                             s=agg.sum("safety_level")).execute(
                                 snapshot=snap)
    assert got["n"].tolist() == [len(rows)]
    assert got["s"].tolist() == [sum(int(r["safety_level"]) for r in rows)]


def test_agg_results_stable_across_compaction(tmp_path):
    sj = fill_store(make_store(tmp_path, segment_rows=50), total=300)
    b = batch_of(120, seed=3, extra=SAFETY)           # churn: rewrite 120
    sj.write(b, lineage={"t": 2})
    sj.flush()
    q = (sj.query().group_by("safety_level")
         .agg(n=agg.count(), top=agg.topk("safety_level", 2)))
    before = q.execute()
    assert sj.dead_rows == 120
    dropped = sj.compact()
    assert dropped == 120 and sj.dead_rows == 0       # 100% reclaimed
    after = q.execute()
    for k in before:
        np.testing.assert_array_equal(before[k], after[k])
    # compaction shrank what a full scan touches
    assert after.stats.rows_scanned == before.stats.rows_scanned - 120


def test_snapshot_survives_concurrent_compaction(tmp_path):
    """A pinned snapshot keeps reading the PRE-compaction files and
    produces the pre-compaction answer — the isolation the pin exists
    for."""
    sj = fill_store(make_store(tmp_path, nparts=1, segment_rows=40),
                    total=200)
    b = batch_of(80, seed=3, extra=SAFETY)
    sj.write(b, lineage={"t": 2})
    sj.flush()
    snap = sj.snapshot()
    pre_watermark = snap.watermark
    assert sj.compact() == 80
    # live partition moved on; the snapshot did not
    res = sj.query().select("id").execute(snapshot=snap)
    assert res.watermark == pre_watermark
    assert res.rows == 200
    with sj.snapshot() as fresh:
        assert fresh.watermark == pre_watermark - 80
    snap.close()


def test_nan_column_disables_zone_map_instead_of_poisoning_it(tmp_path):
    """Review regression: a NaN in a float column must disable that
    column's zone map (never pruned), not poison it to (nan, nan) and
    silently drop matching rows."""
    sj = make_store(tmp_path, nparts=1, segment_rows=10)
    b = batch_of(10, seed=31, extra=SAFETY)
    b["lat"] = np.full(10, np.nan, np.float32)
    b["lat"][3] = 55.0
    sj.write(b, lineage={"t": 1})
    sj.flush()
    with sj.snapshot() as snap:
        zm = snap.parts[0].units[0].zone_map
        assert "lat" not in zm                     # no map, no pruning
        assert "id" in zm                          # others unaffected
    res = sj.query().where(col("lat") >= 50).select("id", "lat").execute()
    assert res.rows == 1 and float(res["lat"][0]) == 55.0
    assert res.stats.segments_pruned == 0
    # != with NaN rows present: the NaN rows match and must survive
    res2 = sj.query().where(col("lat") != 55.0).select("id").execute()
    assert res2.rows == 9


def test_topk_int64_in_range_exact_and_wide_values_rejected():
    """Review regression: topk over int64 must not be squeezed through
    int32 (wrapping values >= 2^31 negative and silently mis-ranking).
    In-range int64 ranks exactly via the reference path; wide values are
    rejected loudly — BOTH segment_topk paths rank within [0, 2^31)."""
    sj = make_store(segment_rows=10_000)
    b = batch_of(8, seed=32, extra=SAFETY)
    b["big"] = np.int64(2) ** 31 - 100 + np.arange(8, dtype=np.int64)
    b["safety_level"] = np.zeros(8, np.int32)      # one group
    sj.write(b, lineage={"t": 1})
    res = (sj.query().group_by("safety_level")
           .agg(top=agg.topk("big", k=3, payload="id")).execute())
    want = [int(b["id"][i]) for i in (7, 6, 5)]    # largest big values
    assert res["top"].tolist() == [want]
    b2 = {k: v.copy() for k, v in b.items()}
    b2["id"] = b["id"] + 100
    b2["big"] = b["big"] + 200                     # crosses 2^31
    sj.write(b2, lineage={"t": 1})
    with pytest.raises(QueryError, match="int32 range"):
        sj.query().group_by("safety_level").agg(
            t=agg.topk("big", k=1)).execute()
    with pytest.raises(QueryError, match="integer"):
        sj.query().group_by("safety_level").agg(
            t=agg.topk("lat", k=1)).execute()


# ---------------------------------------------------------------------------
# layout knobs: sort_key + zone_map_cols end to end
# ---------------------------------------------------------------------------

def test_sort_key_clusters_segments_and_keeps_point_reads(tmp_path):
    sj = make_store(tmp_path, nparts=1, segment_rows=100,
                    zone_map_cols=("id", "country"), sort_key="country")
    b = batch_of(100, seed=5, extra=SAFETY)
    sj.write(b, lineage={"t": 1})
    sj.flush()
    with sj.snapshot() as snap:
        u = snap.parts[0].units[0]
        cols = u.read(("id", "country"))
        assert (np.diff(cols["country"]) >= 0).all()   # clustered
        assert set(u.zone_map) == {"id", "country"}    # only the declared
        assert snap.parts[0].live_mask(cols["id"], 0).all()
    for i in (0, 33, 99):                              # index remapped
        pk = int(b["id"][i])
        assert int(sj.get(pk)["country"]) == int(b["country"][i])
    res = sj.query().where(col("country") >= 200).select("country") \
        .execute()
    assert (res["country"] >= 200).all()
    assert res.rows == int((b["country"] >= 200).sum())


def test_zone_maps_recover_and_legacy_manifests_never_prune(tmp_path):
    import json
    import os
    sj = fill_store(make_store(tmp_path, nparts=1, segment_rows=50),
                    total=150)
    sj.flush()
    man = os.path.join(str(tmp_path), "p0", "MANIFEST.json")
    fresh = make_store(tmp_path, nparts=1).recover()
    r1 = fresh.query().where(col("id") < 40).select("id").execute()
    assert r1.stats.segments_pruned > 0                # restored zone maps
    # strip zone maps (pre-PR-5 manifest): recovery must not prune, and
    # results stay identical
    with open(man) as f:
        m = json.load(f)
    del m["zone_maps"]
    with open(man, "w") as f:
        json.dump(m, f)
    legacy = make_store(tmp_path, nparts=1).recover()
    r2 = legacy.query().where(col("id") < 40).select("id").execute()
    assert r2.stats.segments_pruned == 0
    np.testing.assert_array_equal(r1["id"], r2["id"])


# ---------------------------------------------------------------------------
# plan wiring
# ---------------------------------------------------------------------------

def make_manager(scale=0.002):
    store = RefStore()
    Q.make_reference_tables(store, scale=scale, seed=7)
    return FeedManager(store)


def test_plan_validates_store_layout_knobs():
    mgr = make_manager()

    def plan(**kw):
        return (pipeline(SyntheticAdapter(total=10, frame_size=10), "p")
                .parse(batch_size=10).enrich(Q.Q1).store(**kw))

    with pytest.raises(PlanError, match="zone_map_cols"):
        plan(zone_map_cols=("nope",)).compile(mgr.refstore)
    with pytest.raises(PlanError, match="sort_key"):
        plan(sort_key="nope").compile(mgr.refstore)
    with pytest.raises(PlanError, match="compact"):
        plan(compact=object()).compile(mgr.refstore)
    p = plan(zone_map_cols=("id", "safety_level"), sort_key="country",
             compact={"budget_rows_s": 1000.0}).compile(mgr.refstore)
    spec = p.store_spec
    assert spec.sort_key == "country"
    assert isinstance(spec.compact, CompactionSpec)


def test_feed_handle_query_requires_store_sink():
    mgr = make_manager()
    h = mgr.submit(pipeline(SyntheticAdapter(total=100, frame_size=50),
                            "teeonly")
                   .parse(batch_size=50).enrich(Q.Q1)
                   .tee(lambda b: None))
    try:
        with pytest.raises(RuntimeError, match="store"):
            h.query()
    finally:
        h.join(timeout=60)


def test_plan_store_query_end_to_end():
    mgr = make_manager()
    h = mgr.submit(pipeline(SyntheticAdapter(total=600, frame_size=60,
                                             seed=2), "q-e2e")
                   .parse(batch_size=60).options(num_partitions=2)
                   .enrich(Q.Q1).store())
    stats = h.join(timeout=120)
    assert stats.stored == 600
    res = (h.query().where(col("safety_level") >= 0)
           .group_by("safety_level").agg(n=agg.count()).execute())
    with h.storage.snapshot() as snap:
        want = naive_group(
            naive_rows(snap, lambda r: r["safety_level"] >= 0),
            "safety_level")
    assert res["safety_level"].tolist() == want["keys"]
    assert res["n"].tolist() == want["count"]


# ---------------------------------------------------------------------------
# the acceptance test: queries under concurrent ingestion+repair+compaction
# ---------------------------------------------------------------------------

def test_query_consistency_under_ingest_repair_compaction(tmp_path):
    """While a feed ingests, the repair scheduler re-enriches (rolling ref
    upserts), and the compaction job reclaims, every query must equal the
    naive reference on ITS OWN snapshot, and watermarks must only grow."""
    mgr = make_manager()
    total, batch = 3000, 100
    p = (pipeline(SyntheticAdapter(total=total, frame_size=batch, seed=3,
                                   rate=6000.0), "consist")
         .parse(batch_size=batch)
         .options(num_partitions=2)
         .enrich(Q.Q1)
         .store(spill_dir=str(tmp_path), segment_rows=200,
                refresh=RepairSpec(budget_rows_s=50_000),
                compact=CompactionSpec(budget_rows_s=500_000,
                                       min_dead_frac=0.05,
                                       interval_s=0.02)))
    h = mgr.submit(p)
    t = mgr.refstore["safety_levels"]
    stop = threading.Event()
    churn_errs = []

    def churner():
        rng = np.random.default_rng(11)
        try:
            while not stop.is_set():
                keys = rng.choice(30, 10, replace=False).astype(np.int64)
                t.upsert(keys, safety_level=rng.integers(
                    0, 5, 10).astype(np.int32))
                time.sleep(0.02)
        except BaseException as e:
            churn_errs.append(e)

    ct = threading.Thread(target=churner, daemon=True)
    ct.start()
    try:
        last_live = -1
        checks = 0
        deadline = time.monotonic() + 60
        # keep checking past intake end until >=3 checks ran: the first
        # query may spend the whole (short) intake window compiling the
        # batched-agg concat buckets on a loaded machine; repair and
        # compaction stay live until join(), so late checks still race them
        while (((h.intake is not None and h.intake.is_alive())
                or checks < 3)
               and time.monotonic() < deadline):
            with h.storage.snapshot() as snap:
                res = (h.query().where(col("safety_level") >= 0)
                       .group_by("country")
                       .agg(n=agg.count(), s=agg.sum("safety_level"))
                       .execute(snapshot=snap))
                want = naive_group(
                    naive_rows(snap, lambda r: r["safety_level"] >= 0),
                    "country", value="safety_level")
                live = snap.live_rows
            assert res["country"].tolist() == want["keys"]
            assert res["n"].tolist() == want["count"]
            assert res["s"].tolist() == want["sum"]
            # the watermark may legitimately SHRINK (compaction reclaims
            # versions); the LIVE pk count never does on a filterless plan
            assert live >= last_live
            last_live = live
            checks += 1
            time.sleep(0.05)
    finally:
        stop.set()
        ct.join(10)
        stats = h.join(timeout=120)
    assert not churn_errs, churn_errs[0]
    assert stats.stored == total
    assert checks >= 3                           # the loop really ran
    # post-join: converged store, final query == naive, full reclaim
    assert h.repair is not None and h.repair.converged()
    h.storage.compact()
    assert h.storage.dead_rows == 0
    with h.storage.snapshot() as snap:
        res = h.query().select("id").execute(snapshot=snap)
        assert res.rows == total == snap.live_rows