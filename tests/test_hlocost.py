"""Validate the trip-count-aware HLO cost analyzer against programs with
hand-computable FLOPs — including the scan case where XLA's own
cost_analysis undercounts (the reason hlocost exists)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlocost


def _compile_text(fn, *shapes):
    return jax.jit(fn).lower(*shapes).compile().as_text()


def test_single_matmul_flops_exact():
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    txt = _compile_text(lambda a, b: a @ b, x, w)
    cost = hlocost.analyze_text(txt)
    assert cost.flops == 2 * 128 * 256 * 512
    # traffic >= read A + read B + write C
    assert cost.hbm_bytes >= 4 * (128 * 256 + 256 * 512 + 128 * 512)


def test_scan_flops_scale_with_trip_count():
    """The whole point: 10-layer scan must cost 10x one layer."""
    def body(c, w):
        return jnp.tanh(c @ w), None

    def f(x, ws):
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    one_mm = 2 * 64 * 128 * 128
    for trips in (2, 10):
        ws = jax.ShapeDtypeStruct((trips, 128, 128), jnp.float32)
        txt = _compile_text(f, x, ws)
        cost = hlocost.analyze_text(txt)
        assert cost.flops == trips * one_mm, (trips, cost.flops)
        # XLA's own analysis reports one body only — document the delta
        xla = hlocost.xla_cost_analysis(
            jax.jit(f).lower(x, ws).compile())["flops"]
        assert xla < 1.01 * one_mm     # body counted once, not x trips


def test_nested_scan_weights_multiply():
    def inner(c, w):
        return c @ w, None

    def outer(c, ws):
        y, _ = jax.lax.scan(inner, c, ws)
        return y, None

    def f(x, ws):
        y, _ = jax.lax.scan(outer, x, ws)
        return y

    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((3, 5, 64, 64), jnp.float32)  # 3 x 5 loops
    txt = _compile_text(f, x, ws)
    cost = hlocost.analyze_text(txt)
    assert cost.flops == 15 * 2 * 32 * 64 * 64


def test_grad_scan_counts_fwd_plus_bwd():
    def body(c, w):
        return jnp.tanh(c @ w), None

    def f(x, ws):
        y, _ = jax.lax.scan(body, x, ws)
        return jnp.sum(y)

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)
    txt = _compile_text(jax.grad(f, argnums=1), x, ws)
    cost = hlocost.analyze_text(txt)
    one_mm = 2 * 64 * 128 * 128
    # fwd (1 mm) + bwd (2 mms) per layer = 30 matmuls; XLA may add a
    # cotangent-epilogue matmul outside the loop
    assert 30 * one_mm <= cost.flops <= 33 * one_mm, \
        cost.flops / one_mm


def test_collectives_trip_weighted():
    """A psum inside a scan must count trip-many times."""
    import os
    import subprocess
    import sys
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
import repro
from repro.launch import hlocost
from repro.launch.mesh import compat_make_mesh
mesh = compat_make_mesh((8,), ("model",))
def body(c, w):
    return c @ w, None                      # w sharded on contracting dim
def f(x, ws):
    y, _ = jax.lax.scan(body, x, ws)
    return y
x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
ws = jax.ShapeDtypeStruct((7, 64, 64), jnp.float32)
with mesh:
    txt = jax.jit(f, in_shardings=(
        NamedSharding(mesh, P(None, "model")),
        NamedSharding(mesh, P(None, "model", None)))).lower(x, ws)\
        .compile().as_text()
cost = hlocost.analyze_text(txt)
n = cost.coll_counts.get("all-reduce", 0) + \
    cost.coll_counts.get("reduce-scatter", 0)
assert n >= 7, (n, cost.coll_counts)
print("OK", cost.coll_counts)
"""
    out = subprocess.run([sys.executable, "-c", code], cwd="/root/repo",
                         env={**os.environ, "PYTHONPATH": "src"},
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


def test_dus_traffic_counts_slice_not_buffer():
    """Donated buffers update in place (the decode KV-cache pattern):
    traffic ~ slice size, NOT the 64 MB buffer.  Without donation XLA
    must copy — and the analyzer should report that too."""
    def f(buf, upd):
        return jax.lax.dynamic_update_slice(buf, upd, (0, 0))

    buf = jax.ShapeDtypeStruct((4096, 4096), jnp.float32)   # 64 MB
    upd = jax.ShapeDtypeStruct((8, 8), jnp.float32)         # 256 B
    txt_inplace = jax.jit(f, donate_argnums=0).lower(buf, upd)\
        .compile().as_text()
    cost = hlocost.analyze_text(txt_inplace)
    assert cost.hbm_bytes < 4096 * 4096 * 4 / 4, cost.hbm_bytes
    txt_copy = _compile_text(f, buf, upd)
    cost_copy = hlocost.analyze_text(txt_copy)
    assert cost_copy.hbm_bytes >= 4096 * 4096 * 4    # the copy is real
