"""Record schema / parser / tokenizer invariants (hypothesis properties)."""

import json

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import records
from repro.data.tokenizer import PAD, RESERVED, HashTokenizer


def test_parse_roundtrip_fields():
    src = records.SyntheticTweets(seed=5)
    lines = src.raw_lines(50)
    batch = records.parse_json_lines(lines)
    for i, raw in enumerate(lines):
        rec = json.loads(raw)
        assert batch["id"][i] == rec["id"]
        assert batch["country"][i] == rec["country"]
        assert abs(batch["lat"][i] - rec["lat"]) < 1e-4
        assert batch["created_at"][i] == rec["created_at"]
        assert batch["user_name_hash"][i] == records.hash64(rec["user"])
        words = rec["text"].split()[:records.TEXT_TOKENS]
        for j, w in enumerate(words):
            assert batch["text_tokens"][i, j] == records.hash64(w)
    assert batch["valid"].all()


def test_hash64_stable_and_63bit():
    assert records.hash64("bomb") == records.hash64("bomb")
    assert records.hash64("a") != records.hash64("b")
    for w in ("", "x", "unicode-ü", "long" * 50):
        h = records.hash64(w)
        assert 0 <= h < 2 ** 63


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 100), st.integers(1, 64))
def test_pad_batch_preserves_then_invalidates(n, extra):
    src = records.SyntheticTweets(seed=1)
    b = records.parse_json_lines(src.raw_lines(n))
    p = records.pad_batch(b, n + extra)
    assert p["valid"][:n].all() and not p["valid"][n:].any()
    np.testing.assert_array_equal(p["id"][:n], b["id"])


def test_tokenizer_fold_range():
    tok = HashTokenizer(1000)
    ids = tok.fold(np.array([0, 1, records.hash64("word")], np.int64))
    assert ids[0] == PAD
    assert (ids[1:] >= RESERVED).all() and (ids[1:] < 1000).all()
