"""Kernel-dispatch layer (core/enrich/dispatch.py): Pallas-path results must
match the kernels/*/ref.py oracles on randomized shapes — including the
bucket-padding edge cases (empty batch, batch == bucket boundary, keys
absent from the reference table) — plus the worker micro-batcher and the
double-buffered reference snapshots that ride on it.

No hypothesis dependency: this module must run on minimal installs."""

import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FeedConfig, FeedManager, PartitionHolder, RefStore,
                        pipeline)
from repro.core.computing import ComputingRunner, ComputingSpec
from repro.core.enrich import dispatch, ops
from repro.core.enrich import queries as Q
from repro.core.feed import FeedHandle
from repro.core.intake import SyntheticAdapter
from repro.core.records import SyntheticTweets, parse_json_lines
from repro.core.refdata import KEY_SENTINEL, RefTable
from repro.kernels import dispatch_mode
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.hash_probe import ref as hp_ref
from repro.kernels.segment_reduce import ref as sr_ref
from repro.kernels.spatial_join import ref as sj_ref


def _sorted_keys(rng, nref, capacity):
    out = np.full((capacity,), KEY_SENTINEL, np.int64)
    out[:nref] = np.sort(rng.choice(100_000, nref, replace=False))
    return out


# ---------------------------------------------------------------------------
# equivalence: dispatch pallas path vs the kernel reference oracles
# ---------------------------------------------------------------------------

# edge cases by construction: 0 = empty batch; 512 = exactly one bucket;
# 513 = one past the bucket boundary; 777 = interior; 2048 = larger bucket
@pytest.mark.parametrize("nprobe", [0, 1, 512, 513, 777, 2048])
def test_sorted_join_pallas_matches_ref(nprobe):
    rng = np.random.default_rng(nprobe + 1)
    keys = jnp.asarray(_sorted_keys(rng, 700, 1000))
    # half the probes are absent from the table; one is the sentinel
    probe = rng.integers(0, 200_000, max(nprobe, 1)).astype(np.int64)[:nprobe]
    if nprobe > 1:
        probe[0] = KEY_SENTINEL
    probe = jnp.asarray(probe)
    want_idx, want_found = hp_ref.sorted_probe(probe, keys)
    with dispatch_mode("pallas"):
        got_idx, got_found = dispatch.sorted_join(probe, keys)
    np.testing.assert_array_equal(np.asarray(got_found),
                                  np.asarray(want_found))
    np.testing.assert_array_equal(np.asarray(got_idx), np.asarray(want_idx))


def test_sorted_join_all_keys_absent():
    rng = np.random.default_rng(3)
    keys = jnp.asarray(_sorted_keys(rng, 100, 256))
    probe = jnp.asarray(rng.integers(200_000, 300_000, 600).astype(np.int64))
    with dispatch_mode("pallas"):
        idx, found = dispatch.sorted_join(probe, keys)
    assert not np.asarray(found).any()
    assert (np.asarray(idx) == -1).all()


@pytest.mark.parametrize("nprobe,k", [(0, 3), (256, 1), (300, 4), (512, 8)])
def test_radius_topk_pallas_matches_ref(nprobe, k):
    rng = np.random.default_rng(nprobe + k)
    pts = jnp.asarray(rng.uniform(-10, 10, (nprobe, 2)).astype(np.float32))
    refs = jnp.asarray(rng.uniform(-10, 10, (200, 2)).astype(np.float32))
    valid = jnp.asarray(rng.random(200) < 0.9)
    want = sj_ref.radius_join(pts[:, 0], pts[:, 1], refs[:, 0], refs[:, 1],
                              2.5, k, valid)
    with dispatch_mode("pallas"):
        got = dispatch.radius_topk(pts, refs, 2.5, k, valid)
    np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(want[2]))
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]),
                               rtol=1e-5, atol=1e-6)


def test_radius_count_pallas_matches_ref():
    rng = np.random.default_rng(11)
    pts = jnp.asarray(rng.uniform(-5, 5, (700, 2)).astype(np.float32))
    refs = jnp.asarray(rng.uniform(-5, 5, (300, 2)).astype(np.float32))
    _, _, want = sj_ref.radius_join(pts[:, 0], pts[:, 1],
                                    refs[:, 0], refs[:, 1], 1.5, 1, None)
    with dispatch_mode("pallas"):
        got = dispatch.radius_count(pts, refs, 1.5)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32])
@pytest.mark.parametrize("r,s", [(0, 4), (512, 33), (700, 129)])
def test_segment_sum_pallas_matches_ref(dtype, r, s):
    rng = np.random.default_rng(r + s)
    vals = jnp.asarray(rng.integers(0, 100, r).astype(dtype))
    seg = jnp.asarray(rng.integers(0, s, r).astype(np.int32))
    valid = jnp.asarray(rng.random(r) < 0.8)
    want = sr_ref.segment_sum(jnp.where(valid, vals, 0), seg, s)
    with dispatch_mode("pallas"):
        got = dispatch.segment_sum(vals, seg, s, valid)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
    with dispatch_mode("pallas"):
        cnt = dispatch.segment_count(seg, s, valid)
    want_cnt = sr_ref.segment_sum(
        jnp.where(valid, 1, 0).astype(jnp.int32), seg, s)
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(want_cnt))


def test_segment_sum_int64_falls_back_to_reference():
    """The MXU/VPU kernel accumulates in 32 bits: int64 inputs must take the
    XLA path and keep exact 64-bit sums."""
    vals = jnp.asarray(np.array([2**40, 2**40, 7], np.int64))
    seg = jnp.asarray(np.array([0, 0, 1], np.int32))
    dispatch.reset_bucket_stats()
    with dispatch_mode("pallas"):
        got = dispatch.segment_sum(vals, seg, 2)
    np.testing.assert_array_equal(np.asarray(got), [2**41, 7])
    assert not any(op == "segment_sum" for op, _ in dispatch.bucket_stats())


def test_segment_topk_dispatch_matches_ops_ref():
    rng = np.random.default_rng(5)
    vals = jnp.asarray(rng.integers(0, 1000, 300).astype(np.int32))
    seg = jnp.asarray(rng.integers(0, 12, 300).astype(np.int32))
    pay = jnp.asarray(np.arange(300, dtype=np.int32))
    want = ops._segment_topk_ref(vals, seg, pay, 12, 3)
    dispatch.reset_bucket_stats()
    with dispatch_mode("pallas"):
        got = dispatch.segment_topk(vals, seg, pay, 12, 3)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))
    # the kernel path actually engaged (satellite: segment_topk is no
    # longer reference-only)
    assert any(op == "segment_topk" for op, _ in dispatch.bucket_stats())


# dense ties (values mod 7), segments with < k rows, empty segments, an
# empty batch, and bucket-boundary row counts — the composite-sort
# oracle's tie-break (value desc, row asc) must survive the kernel
@pytest.mark.parametrize("r,s,k", [(0, 5, 2), (64, 200, 4), (300, 12, 3),
                                   (512, 1, 1), (700, 129, 8),
                                   (1000, 40, 5)])
def test_segment_topk_kernel_matches_ref_randomized(r, s, k):
    rng = np.random.default_rng(r + s + k)
    vals = jnp.asarray((rng.integers(0, 700, r) % 7).astype(np.int32))
    seg = jnp.asarray(rng.integers(0, s, max(r, 1)
                                   ).astype(np.int32)[:r])
    pay = jnp.asarray(rng.integers(0, 10_000, r).astype(np.int64))
    valid = jnp.asarray(rng.random(r) < 0.8)
    want = ops._segment_topk_ref(vals, seg, pay, s, k, valid)
    with dispatch_mode("pallas"):
        got = dispatch.segment_topk(vals, seg, pay, s, k, valid)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))


def test_segment_topk_uint32_above_int31_falls_back_exactly():
    """The kernel ranks in int32; uint32 values >= 2^31 would wrap
    negative there — they must take the reference path and rank by true
    magnitude."""
    vals = jnp.asarray(np.array([3_000_000_000, 5, 7], np.uint32))
    seg = jnp.asarray(np.zeros(3, np.int32))
    pay = jnp.asarray(np.array([10, 20, 30], np.int32))
    dispatch.reset_bucket_stats()
    with dispatch_mode("pallas"):
        got_pay, got_val = dispatch.segment_topk(vals, seg, pay, 1, 2)
    assert got_pay[0].tolist() == [10, 30]         # 3e9 really ranks first
    assert not any(op == "segment_topk" for op, _ in
                   dispatch.bucket_stats())


def test_segment_topk_outside_kernel_envelope_falls_back():
    """Q3's 50K-segment top-3 must keep the reference sort (the kernel's
    winner tables are VMEM-bounded), as must 64-bit values."""
    rng = np.random.default_rng(8)
    vals = jnp.asarray(rng.integers(0, 100, 400).astype(np.int32))
    seg = jnp.asarray(rng.integers(0, 5000, 400).astype(np.int32))
    pay = jnp.asarray(np.arange(400, dtype=np.int32))
    dispatch.reset_bucket_stats()
    with dispatch_mode("pallas"):
        got = dispatch.segment_topk(vals, seg, pay, 5000, 3)
        got64 = dispatch.segment_topk(vals.astype(jnp.int64), seg, pay,
                                      12, 3)
    want = ops._segment_topk_ref(vals, seg, pay, 5000, 3)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    want64 = ops._segment_topk_ref(vals.astype(jnp.int64), seg, pay, 12, 3)
    np.testing.assert_array_equal(np.asarray(got64[0]),
                                  np.asarray(want64[0]))
    assert not any(op == "segment_topk" for op, _ in
                   dispatch.bucket_stats())


def test_flash_attention_policy_routes_to_pallas():
    """The fourth kernel wrapper honors the same global policy."""
    rng = np.random.default_rng(9)
    q, k, v = (jnp.asarray(rng.normal(size=(1, 128, 4, 64))
                           .astype(np.float32)) for _ in range(3))
    want = fa_ref.flash_attention(q, k, v, causal=True)
    with dispatch_mode("pallas"):
        got = fa_ops.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


# ---------------------------------------------------------------------------
# shape bucketing
# ---------------------------------------------------------------------------

def test_bucket_rows_power_of_two():
    assert dispatch.bucket_rows(1) == dispatch._config.bucket_min
    assert dispatch.bucket_rows(600) == 1024
    assert dispatch.bucket_rows(1024) == 1024
    assert dispatch.bucket_rows(1025) == 2048
    assert dispatch.bucket_rows(800, minimum=420) == 840  # 420 * 2^k ladder
    assert dispatch.bucket_rows(900, minimum=420) == 1680


def test_concat_rows_batches_units_in_scan_order():
    """The batched-aggregation planner: empty slices drop, order is
    preserved, a single part avoids the copy, and the hit is recorded
    against the row bucket the dispatches will ride."""
    parts = [
        {"a": np.arange(3, dtype=np.int64), "b": np.arange(3.0)},
        {"a": np.empty(0, np.int64), "b": np.empty(0)},
        {"a": np.arange(3, 8, dtype=np.int64), "b": np.arange(3.0, 8.0)},
    ]
    cols, n = dispatch.concat_rows(parts)
    assert n == 8
    np.testing.assert_array_equal(cols["a"], np.arange(8))
    np.testing.assert_array_equal(cols["b"], np.arange(8.0))
    assert dispatch.concat_rows([]) == ({}, 0)
    assert dispatch.concat_rows([{"a": np.empty(0, np.int64)}]) == ({}, 0)
    one, n1 = dispatch.concat_rows([{"a": np.arange(4)}])
    assert n1 == 4 and one["a"].tolist() == [0, 1, 2, 3]


def test_path_tape_records_dispatch_routes():
    """Satellite: the 64-bit XLA fallback is explicit in stats — a
    thread-local tape splits kernel vs fallback dispatches per query."""
    vals = jnp.asarray(np.array([2 ** 40, 1], np.int64))
    seg = jnp.asarray(np.array([0, 1], np.int32))
    dispatch.path_tape_start()
    dispatch.segment_sum(vals, seg, 2)
    tape = dispatch.path_tape_stop()
    assert tape == {("segment_sum", "xla_64bit"): 1}
    # the tape is cleared on stop, and the global counters saw it too
    dispatch.path_tape_start()
    assert dispatch.path_tape_stop() == {}
    assert dispatch.path_stats().get(("segment_sum", "xla_64bit"), 0) >= 1
    # int32 off the kernel envelope routes "reference", never "xla_64bit"
    dispatch.path_tape_start()
    with dispatch_mode("reference"):
        dispatch.segment_sum(vals.astype(jnp.int32), seg, 2)
    tape = dispatch.path_tape_stop()
    assert tape == {("segment_sum", "reference"): 1}


def test_nearby_sizes_share_a_compiled_bucket():
    rng = np.random.default_rng(17)
    keys = jnp.asarray(_sorted_keys(rng, 500, 1000))
    dispatch.reset_bucket_stats()
    with dispatch_mode("pallas"):
        for b in (600, 900, 1024):   # all pad to the 1024 bucket
            dispatch.sorted_join(
                jnp.asarray(rng.integers(0, 1000, b).astype(np.int64)), keys)
        dispatch.sorted_join(
            jnp.asarray(rng.integers(0, 1000, 1025).astype(np.int64)), keys)
    stats = dispatch.bucket_stats()
    assert stats[("sorted_join", 1024)] == 3
    assert stats[("sorted_join", 2048)] == 1


# ---------------------------------------------------------------------------
# worker micro-batching (cross-partition coalescing)
# ---------------------------------------------------------------------------

def _handle(coalesce_rows, model="per_batch"):
    cfg = FeedConfig(name="t", batch_size=50, coalesce_rows=coalesce_rows,
                     model=model)
    return FeedHandle(cfg, FeedManager(RefStore()),
                      SyntheticAdapter(total=0, frame_size=50))


def test_coalesce_merges_backlog_up_to_row_budget():
    src = SyntheticTweets(seed=3)
    frames = list(src.batches(250, 50))            # 5 frames x 50 rows
    holder = PartitionHolder(("t:intake", 0), capacity=8)
    for f in frames[1:]:
        holder.push(f)
    h = _handle(coalesce_rows=170)
    merged = h._coalesce(holder, frames[0])
    # 50 + 50 + 50 + 50 crosses the 170-row budget at 200; 5th frame stays
    assert len(merged) == 200
    assert holder.depth == 1
    assert h.stats.coalesced_frames == 3
    assert merged[:50] == frames[0]                # order preserved


def test_coalesce_disabled_and_per_record_passthrough():
    src = SyntheticTweets(seed=3)
    frames = list(src.batches(100, 50))
    for kwargs in ({"coalesce_rows": 0},
                   {"coalesce_rows": 500, "model": "per_record"}):
        holder = PartitionHolder(("t:intake", 0), capacity=8)
        holder.push(frames[1])
        h = _handle(**kwargs)
        assert h._coalesce(holder, frames[0]) is frames[0]
        assert holder.depth == 1


def test_coalesce_never_crosses_stop_record():
    src = SyntheticTweets(seed=3)
    frames = list(src.batches(100, 50))
    holder = PartitionHolder(("t:intake", 0), capacity=8)
    holder.close()                                  # StopRecord at the head
    h = _handle(coalesce_rows=1000)
    assert h._coalesce(holder, frames[0]) is frames[0]


def test_runner_bucket_pads_oversized_coalesced_batch():
    """A coalesced frame bigger than the compiled batch size pads to the
    batch_size * 2^k ladder instead of compiling per exact size."""
    store = RefStore()
    Q.make_reference_tables(store, scale=0.002, seed=7)
    runner = ComputingRunner(ComputingSpec(Q.Q1, 420), store)
    src = SyntheticTweets(seed=5)
    frame = next(iter(src.batches(600, 600)))       # 600 rows > 420
    out = runner.run(frame)
    assert out["id"].shape[0] == 840                # 420 * 2
    assert int(out["valid"].sum()) == 600


def test_feed_end_to_end_with_coalescing_stores_every_record():
    store = RefStore()
    Q.make_reference_tables(store, scale=0.002, seed=7)
    mgr = FeedManager(store)
    p = (pipeline(SyntheticAdapter(total=1000, frame_size=50, seed=11),
                  "coal")
         .parse(batch_size=50)
         .options(num_partitions=2, coalesce_rows=400)
         .enrich(Q.Q1).store())
    h = mgr.submit(p)
    stats = h.join(timeout=300)
    assert stats.stored == 1000
    # invocations can only shrink under coalescing, never grow
    assert stats.computing.invocations <= stats.frames_in


# ---------------------------------------------------------------------------
# double-buffered reference snapshots
# ---------------------------------------------------------------------------

def test_snapshot_consistent_under_concurrent_upserts():
    """Writers mutate while readers snapshot: every snapshot must be an
    internally consistent sorted view (keys aligned with payload), never a
    torn one."""
    t = RefTable("x", 4096, {"v": np.int64})
    keys = np.arange(512, dtype=np.int64)
    t.upsert(keys, v=keys * 2)
    stop = threading.Event()
    errs = []

    def writer():
        i = 512
        while not stop.is_set():
            ks = np.arange(i, i + 8, dtype=np.int64) % 3000
            t.upsert(ks, v=ks * 2)
            i += 8

    def reader():
        try:
            for _ in range(300):
                s = t.snapshot()
                key = s.arrays["key"][:s.size]
                assert (np.diff(key) > 0).all(), "unsorted/torn keys"
                assert (key != KEY_SENTINEL).all()
                np.testing.assert_array_equal(s.arrays["v"][:s.size],
                                              key * 2)
        except BaseException as e:   # surfaced after join
            errs.append(e)

    w = threading.Thread(target=writer, daemon=True)
    readers = [threading.Thread(target=reader) for _ in range(3)]
    w.start()
    for r in readers:
        r.start()
    for r in readers:
        r.join(60)
    stop.set()
    w.join(10)
    assert not errs, errs[0]


def test_snapshot_cached_until_write_then_fresh():
    t = RefTable("y", 64, {"v": np.int32})
    t.upsert(np.array([3, 1], np.int64), v=np.array([30, 10], np.int32))
    s1 = t.snapshot()
    assert s1 is t.snapshot()                       # cached, zero-copy
    t.upsert(np.array([2], np.int64), v=np.array([20], np.int32))
    s2 = t.snapshot()
    assert s2.version > s1.version
    np.testing.assert_array_equal(s2.arrays["key"][:3], [1, 2, 3])
    np.testing.assert_array_equal(s2.arrays["v"][:3], [10, 20, 30])
    # the old snapshot is immutable history (Model 2: state as of pickup)
    np.testing.assert_array_equal(s1.arrays["key"][:2], [1, 3])
