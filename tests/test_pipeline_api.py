"""Plan-API tests: declarative pipeline compilation (fusion, validation),
multi-sink fan-out, the baseline-only FeedConfig entry point, and
feed-lifecycle fixes.

Deliberately hypothesis-free: CI runs this module in a minimal container
(`pip install -e . pytest` only) so API regressions surface even where the
property-test extras are not installed.
"""

import threading

import numpy as np
import pytest

from repro.core import (ComputingRunner, ComputingSpec, FeedConfig,
                        FeedManager, PlanError, RefStore, SyntheticAdapter,
                        pipeline)
from repro.core.enrich import queries as Q
from repro.core.enrich.dispatch import dispatch_mode
from repro.core.feed import COALESCE_DEFAULT_BATCHES
from repro.core.intake import Adapter
from repro.core.records import SyntheticTweets, parse_json_lines


def make_manager(scale=0.002):
    store = RefStore()
    Q.make_reference_tables(store, scale=scale, seed=7)
    return FeedManager(store)


def scan_by_id(storage):
    """Storage contents as {id: row dict}, for order-independent compare."""
    rows = {}
    for chunk in storage.scan():
        for i in range(chunk["id"].shape[0]):
            rows[int(chunk["id"][i])] = {k: chunk[k][i] for k in chunk}
    return rows


# ---------------------------------------------------------------------------
# fusion: fused chain == sequential stages, at <= half the invocations
# ---------------------------------------------------------------------------

def test_fused_chain_bitwise_matches_sequential_reference_dispatch():
    """Runner-level: one fused Q1>Q2 apply produces bit-identical columns
    to applying Q1 then Q2 as separate computing jobs (reference dispatch,
    so both sides run the exact same jnp operator implementations)."""
    mgr = make_manager()
    frame = SyntheticTweets(seed=21).raw_lines(128)
    with dispatch_mode("reference"):
        fused = ComputingRunner(
            ComputingSpec(Q.Q1.then(Q.Q2), 128), mgr.refstore,
            mgr.predeploy)
        out_fused = fused.run(list(frame))

        seq1 = ComputingRunner(ComputingSpec(Q.Q1, 128), mgr.refstore,
                               mgr.predeploy)
        seq2 = ComputingRunner(ComputingSpec(Q.Q2, 128), mgr.refstore,
                               mgr.predeploy)
        out_seq = seq2.run(seq1.run(list(frame)))
    for col in ("safety_level", "religious_population"):
        np.testing.assert_array_equal(out_fused[col], out_seq[col])
    # the fused runner made ONE invocation where sequential made two
    assert fused.stats.invocations == 1
    assert seq1.stats.invocations + seq2.stats.invocations == 2


def _run_single_udf_feed(mgr, name, udf, total, frame, seed):
    p = (pipeline(SyntheticAdapter(total=total, frame_size=frame,
                                   seed=seed), name)
         .parse(batch_size=frame)
         .options(num_partitions=1, coalesce_rows=0)
         .enrich(udf).store())
    h = mgr.submit(p)
    stats = h.join(timeout=120)
    return h, stats


def test_fused_plan_acceptance_criterion():
    mgr = make_manager()
    total, frame = 600, 100

    plan = (pipeline(SyntheticAdapter(total=total, frame_size=frame,
                                      seed=5), "fused")
            .parse(batch_size=frame)
            .options(num_partitions=1, coalesce_rows=0)
            .enrich(Q.Q1).enrich(Q.Q2)
            .store())
    h_fused = mgr.submit(plan)
    fused_stats = h_fused.join(timeout=120)
    assert fused_stats.stored == total

    h_q1, s_q1 = _run_single_udf_feed(mgr, "seq-q1", Q.Q1, total, frame, 5)
    h_q2, s_q2 = _run_single_udf_feed(mgr, "seq-q2", Q.Q2, total, frame, 5)

    # <= half the computing-job invocations of the two sequential feeds
    seq_inv = s_q1.computing.invocations + s_q2.computing.invocations
    assert fused_stats.computing.invocations * 2 <= seq_inv

    fused_rows = scan_by_id(h_fused.storage)
    q1_rows = scan_by_id(h_q1.storage)
    q2_rows = scan_by_id(h_q2.storage)
    assert set(fused_rows) == set(q1_rows) == set(q2_rows)
    for rid, row in fused_rows.items():
        np.testing.assert_array_equal(row["safety_level"],
                                      q1_rows[rid]["safety_level"])
        np.testing.assert_array_equal(row["religious_population"],
                                      q2_rows[rid]["religious_population"])

    # per-stage observability: both stages were invoked per batch
    per = fused_stats.computing.per_stage
    assert per["q1_safety_level"].invocations == \
        fused_stats.computing.invocations
    assert per["q2_religious_population"].state_builds >= 1


def test_per_stage_state_reuse_version_gated():
    """refresh="version": each fused stage's state is rebuilt only when a
    table THAT stage reads changes; quiet stages reuse."""
    mgr = make_manager()
    plan = (pipeline(SyntheticAdapter(total=500, frame_size=100, seed=9),
                     "gated")
            .parse(batch_size=100, refresh="version")
            .options(num_partitions=1, coalesce_rows=0)
            .enrich(Q.Q2).enrich(Q.Q3)
            .store())
    stats = mgr.submit(plan).join(timeout=120)
    per = stats.computing.per_stage
    for stage in ("q2_religious_population", "q3_largest_religions"):
        assert per[stage].state_builds == 1          # built once...
        assert per[stage].state_reuses >= 1          # ...then reused


# ---------------------------------------------------------------------------
# multi-sink fan-out
# ---------------------------------------------------------------------------

def test_tee_delivers_every_batch_to_every_sink_exactly_once():
    mgr = make_manager()
    lock = threading.Lock()
    got = {"a": [], "b": []}

    def make_sink(key):
        def sink(batch):
            with lock:
                got[key].append(batch)
        return sink

    plan = (pipeline(SyntheticAdapter(total=400, frame_size=50, seed=4),
                     "tee")
            .parse(batch_size=50)
            .options(num_partitions=2, coalesce_rows=0)
            .enrich(Q.Q1)
            .tee(make_sink("a"), name="a")
            .tee(make_sink("b"), name="b")
            .store())
    h = mgr.submit(plan)
    stats = h.join(timeout=120)

    inv = stats.computing.invocations
    assert stats.sink_batches == {"a": inv, "b": inv, "store": inv}
    assert h.storage.batches == inv
    for key in ("a", "b"):
        ids = np.concatenate(
            [b["id"][b["valid"]] for b in got[key]])
        assert len(ids) == 400                       # every record...
        assert len(np.unique(ids)) == 400            # ...exactly once
    assert stats.stored == 400                       # storage sink too


def test_failing_tee_sink_surfaces_error_instead_of_deadlocking():
    """A tee consumer that raises must not wedge the feed: its holder
    fail-fast closes (unblocking producers), healthy sinks keep receiving,
    and join() re-raises the sink's error."""
    mgr = make_manager()

    def bad_sink(batch):
        raise RuntimeError("sink exploded")

    plan = (pipeline(SyntheticAdapter(total=400, frame_size=50, seed=6),
                     "badsink")
            .parse(batch_size=50)
            .options(num_partitions=1, coalesce_rows=0)
            .enrich(Q.Q1)
            .tee(bad_sink, name="bad")
            .store())
    h = mgr.submit(plan)
    with pytest.raises(RuntimeError, match="sink exploded"):
        h.join(timeout=30)
    # the healthy storage sink still got every record
    assert h.storage.stored == 400


def test_all_sinks_dead_winds_feed_down_promptly():
    """If a feed's ONLY sink dies, workers stop enriching (discard-drain),
    the adapter is stopped, and join() surfaces the sink error — instead
    of silently burning the rest of a (possibly unbounded) stream."""
    mgr = make_manager()

    def bad_sink(batch):
        raise RuntimeError("only sink exploded")

    plan = (pipeline(SyntheticAdapter(total=10_000_000, frame_size=50,
                                      seed=7), "allsinksdead")
            .parse(batch_size=50)
            .options(num_partitions=1, coalesce_rows=0)
            .enrich(Q.Q1)
            .tee(bad_sink, name="only"))
    h = mgr.submit(plan)
    with pytest.raises(RuntimeError, match="only sink exploded"):
        h.join(timeout=60)
    assert h.adapter._stop.is_set()
    # the feed aborted long before the 10M-record stream was enriched
    assert sum(r.stats.invocations for r in h.runners) < 100


def test_same_shaped_plans_with_different_predicates_do_not_collide():
    """Two plans whose auto-generated stage names line up must each run
    their OWN compiled predicate (the predeploy cache keys on function
    identity, not just name + shapes)."""
    mgr = make_manager()

    def plan_with_threshold(name, thr):
        return (pipeline(SyntheticAdapter(total=200, frame_size=50,
                                          seed=12), name)
                .parse(batch_size=50)
                .options(num_partitions=1, coalesce_rows=0)
                .enrich(Q.Q1)
                .filter(lambda b: b["country"] >= thr)  # default stage name
                .store())

    h_all = mgr.submit(plan_with_threshold("keep-all", 0))
    assert h_all.join(timeout=120).stored == 200
    h_none = mgr.submit(plan_with_threshold("keep-none", 10_000))
    assert h_none.join(timeout=120).stored == 0


def test_filter_stage_fuses_and_drops_rows():
    mgr = make_manager()
    plan = (pipeline(SyntheticAdapter(total=500, frame_size=100, seed=8),
                     "filtered")
            .parse(batch_size=100)
            .options(num_partitions=1, coalesce_rows=0)
            .enrich(Q.Q1)
            .filter(lambda b: b["country"] < 128, name="low_country")
            .store())
    h = mgr.submit(plan)
    stats = h.join(timeout=120)
    # ground truth from the deterministic source (same frame batching —
    # the RNG stream position depends on it)
    src = SyntheticTweets(seed=8)
    expected = sum(int((parse_json_lines(f)["country"] < 128).sum())
                   for f in src.batches(500, 100))
    assert stats.stored == expected
    for rid, row in scan_by_id(h.storage).items():
        assert int(row["country"]) < 128
    # the filter fused into the enrich chain: still one apply per batch
    by_name = {k: v for k, v in mgr.predeploy.by_name.items()
               if k.startswith("apply:")}
    assert len(by_name) == 1
    assert stats.computing.invocations == 5


def test_project_restricts_sink_columns():
    mgr = make_manager()
    plan = (pipeline(SyntheticAdapter(total=200, frame_size=100, seed=2),
                     "proj")
            .parse(batch_size=100)
            .options(num_partitions=1)
            .enrich(Q.Q1)
            .project("safety_level")
            .store())
    h = mgr.submit(plan)
    stats = h.join(timeout=120)
    assert stats.stored == 200
    for chunk in h.storage.scan():
        assert sorted(chunk) == ["id", "safety_level", "valid"]


# ---------------------------------------------------------------------------
# compile-time validation
# ---------------------------------------------------------------------------

def _adapter(n=10):
    return SyntheticAdapter(total=n, frame_size=n)


def test_missing_ref_table_raises_at_compile_time():
    empty = RefStore()
    p = pipeline(_adapter(), "bad").enrich(Q.Q1).store()
    with pytest.raises(PlanError, match="safety_levels"):
        p.compile(empty)
    # ...and nothing was started or registered
    mgr = FeedManager(empty)
    with pytest.raises(PlanError):
        mgr.submit(pipeline(_adapter(), "bad").enrich(Q.Q1).store())
    assert mgr.feeds == {}


def test_enrich_after_store_raises_at_compile_time():
    mgr = make_manager()
    p = pipeline(_adapter(), "bad2").store().enrich(Q.Q1)
    with pytest.raises(PlanError, match="after a sink"):
        p.compile(mgr.refstore)


def test_plan_without_sink_raises():
    mgr = make_manager()
    with pytest.raises(PlanError, match="no sink"):
        pipeline(_adapter(), "nosink").enrich(Q.Q1).compile(mgr.refstore)


def test_double_store_and_double_project_raise():
    mgr = make_manager()
    with pytest.raises(PlanError, match="store"):
        pipeline(_adapter(), "p1").store().store().compile(mgr.refstore)
    with pytest.raises(PlanError, match="project"):
        (pipeline(_adapter(), "p2").project("id").project("country")
         .store().compile(mgr.refstore))


def test_unknown_project_column_raises_at_compile_time():
    mgr = make_manager()
    p = (pipeline(_adapter(), "p3").enrich(Q.Q1)
         .project("not_a_column").store())
    with pytest.raises(PlanError, match="not_a_column"):
        p.compile(mgr.refstore)


def test_stage_dtype_validation_at_compile_time():
    """A UDF that reads a column the schema does not have fails in
    compile(), not in a worker thread mid-feed."""
    def bad_apply(batch, state, refs):
        return {"x": batch["no_such_column"] + 1}

    bad = Q.EnrichUDF("bad_udf", (), None, bad_apply, "broken")
    mgr = make_manager()
    p = pipeline(_adapter(), "p4").enrich(Q.Q1).enrich(bad).store()
    with pytest.raises(PlanError, match="bad_udf"):
        p.compile(mgr.refstore)


def test_non_batch_aligned_output_raises_at_compile_time():
    def scalarizing(batch, state, refs):
        return {"x": batch["country"].sum()}          # rank-0 output

    bad = Q.EnrichUDF("scalarizing", (), None, scalarizing, "broken")
    mgr = make_manager()
    with pytest.raises(PlanError, match="batch-aligned"):
        (pipeline(_adapter(), "p5").enrich(bad).store()
         .compile(mgr.refstore))


def test_unknown_option_raises():
    with pytest.raises(PlanError, match="unknown option"):
        pipeline(_adapter(), "p6").options(frobnicate=1)


# ---------------------------------------------------------------------------
# FeedConfig is baseline/runtime-only now + feed lifecycle
# ---------------------------------------------------------------------------

def test_start_rejects_framework_new():
    """The deprecated framework='new' shim lowering is gone: start() is
    the baseline rigs' entry point only, and points plan-shaped callers
    at pipeline()/submit."""
    mgr = make_manager()
    cfg = FeedConfig(name="shim", udf=Q.Q1, batch_size=100,
                     num_partitions=2)
    with pytest.raises(ValueError, match="pipeline"):
        mgr.start(cfg, SyntheticAdapter(total=300, frame_size=100, seed=1))
    assert "shim" not in mgr.feeds        # nothing half-registered


def test_feed_name_reusable_after_join():
    """Completed feeds deregister: same name + holder IDs start cleanly."""
    mgr = make_manager()
    for round_ in range(2):
        p = (pipeline(SyntheticAdapter(total=200, frame_size=50,
                                       seed=round_), "again")
             .parse(batch_size=50).options(num_partitions=2)
             .enrich(Q.Q1).store())
        stats = mgr.submit(p).join(timeout=120)
        assert stats.stored == 200
    assert "again" not in mgr.feeds
    assert mgr.holder_manager.partitions("again:intake") == []


def test_feed_name_reusable_after_stop():
    mgr = make_manager()
    for round_ in range(2):
        adapter = SyntheticAdapter(total=100_000, frame_size=50,
                                   rate=20_000.0)
        h = mgr.submit(pipeline(adapter, "stopper").parse(batch_size=50)
                       .store())
        h.stop()
        stats = h.join(timeout=60)
        assert stats.stored == stats.records_in


class DictFrameAdapter(Adapter):
    """Yields pre-parsed tensor frames (dict-of-columns), as a balanced
    intake would."""

    def __init__(self, total, frame_size, seed=0):
        super().__init__()
        self.total, self.frame_size = total, frame_size
        self.src = SyntheticTweets(seed=seed)

    def frames(self):
        for f in self.src.batches(self.total, self.frame_size):
            if self._stop.is_set():
                return
            yield parse_json_lines(f)


def test_insert_baseline_counts_rows_not_columns_for_dict_frames():
    mgr = make_manager()
    cfg = FeedConfig(name="ins-dict", udf=Q.Q1, batch_size=50,
                     framework="insert")
    h = mgr.start(cfg, DictFrameAdapter(total=150, frame_size=50))
    stats = h.join(timeout=120)
    assert stats.stored == 150
    assert stats.records_in == 150        # was 8 per frame (column count)
    assert stats.frames_in == 3


def test_intake_counts_rows_for_dict_frames():
    mgr = make_manager()
    h = mgr.submit(pipeline(DictFrameAdapter(total=150, frame_size=50),
                            "new-dict")
                   .parse(batch_size=50).options(num_partitions=1)
                   .enrich(Q.Q1).store())
    stats = h.join(timeout=120)
    assert stats.records_in == 150
    assert stats.stored == 150


def test_coalesce_rows_default_resolution():
    new = FeedConfig(name="a", batch_size=100)
    assert new.resolved_coalesce_rows == COALESCE_DEFAULT_BATCHES * 100
    assert FeedConfig(name="b", batch_size=100,
                      coalesce_rows=7).resolved_coalesce_rows == 7
    assert FeedConfig(name="c", batch_size=100,
                      coalesce_rows=0).resolved_coalesce_rows == 0
    for baseline in ("current", "balanced", "insert"):
        assert FeedConfig(name="d", batch_size=100,
                          framework=baseline).resolved_coalesce_rows == 0


# ---------------------------------------------------------------------------
# feedlint R1 fixes: registry reads/writes are critical sections
# ---------------------------------------------------------------------------

def test_holder_lookup_safe_against_concurrent_register_churn():
    """Regression for the feedlint R1 finding: lookup() used to read the
    registry dict lock-free, racing register/unregister from scale
    events.  Stable holders must stay resolvable while other holder IDs
    churn."""
    from repro.core.partition_holder import (PartitionHolder,
                                             PartitionHolderManager)
    hm = PartitionHolderManager()
    stable = [hm.register(PartitionHolder(("job", i), 4)) for i in range(4)]
    stop = threading.Event()
    errs = []

    def churn(base):
        # disjoint id ranges per thread: register() correctly rejects
        # duplicate ids, so colliding ranges would be a test bug
        i = base
        try:
            while not stop.is_set():
                h = PartitionHolder(("job", i), 4)
                hm.register(h)
                hm.unregister(h.holder_id)
                i += 1
        except BaseException as e:      # pragma: no cover - the regression
            errs.append(e)

    def read():
        try:
            while not stop.is_set():
                for i, h in enumerate(stable):
                    assert hm.lookup("job", i) is h
        except BaseException as e:      # pragma: no cover - the regression
            errs.append(e)

    threads = [threading.Thread(target=churn, args=(100,)),
               threading.Thread(target=churn, args=(1_000_000,)),
               threading.Thread(target=read),
               threading.Thread(target=read)]
    for t in threads:
        t.start()
    import time
    time.sleep(0.4)
    stop.set()
    for t in threads:
        t.join(timeout=10)
    assert errs == []


def test_concurrent_submits_of_same_name_admit_exactly_one():
    """Regression for the feedlint R1 finding: submit()'s name check and
    registry insert are now one critical section, so racing submits of
    the same feed name cannot both win (one feed would be orphaned —
    running threads, unreachable handle)."""
    mgr = make_manager()
    barrier = threading.Barrier(4)
    results = []

    def submit_one(seed):
        p = (pipeline(SyntheticAdapter(total=50, frame_size=50, seed=seed),
                      "dup").parse(batch_size=50).store())
        barrier.wait()
        try:
            results.append(mgr.submit(p))
        except KeyError:
            results.append(None)

    threads = [threading.Thread(target=submit_one, args=(i,))
               for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
    winners = [h for h in results if h is not None]
    assert len(winners) == 1
    stats = winners[0].join(timeout=60)
    assert stats.stored == 50
    assert "dup" not in mgr.feeds       # deregistered: name reusable
