"""Roofline summary: reads the dry-run artifacts (launch_artifacts/dryrun)
and emits the per-(arch x shape x mesh) roofline terms as CSV — the §Perf
scoreboard.  Run ``python -m repro.launch.dryrun --all`` first."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

FIG = "roofline"
ART = os.path.join(os.path.dirname(__file__), "..", "launch_artifacts",
                   "dryrun")


def main() -> None:
    files = sorted(glob.glob(os.path.join(ART, "*.json")))
    if not files:
        emit(FIG, "no_artifacts", 0, "", "run repro.launch.dryrun --all")
        return
    for path in files:
        r = json.load(open(path))
        cell = f"{r['arch']}__{r['shape']}__{r['mesh']}"
        if r["status"] == "skip":
            emit(FIG, cell, 0, "skip", r["reason"])
            continue
        if r["status"] != "ok":
            emit(FIG, cell, 0, r["status"], r.get("error", "")[:80])
            continue
        rf = r["roofline"]
        total = rf["compute_s"] + rf["memory_s"] + rf["collective_s"]
        bound = rf[f"{rf['dominant']}_s"]
        emit(FIG, f"{cell}_dominant", rf["dominant"], "",
             f"c={rf['compute_s']:.4f}s m={rf['memory_s']:.4f}s "
             f"coll={rf['collective_s']:.4f}s")
        emit(FIG, f"{cell}_roofline_frac", rf["compute_s"] / max(bound,
                                                                 1e-12),
             "", "compute_term/dominant_term (1.0 = compute-bound)")
        emit(FIG, f"{cell}_useful_ratio", round(r["useful_ratio"], 3), "",
             r["model_flops_formula"])
        emit(FIG, f"{cell}_hbm_fit", int(r["hbm_fit"]), "bool",
             "arg+temp+out GB/dev="
             f"{(r['arg_bytes_per_dev'] + r['temp_bytes_per_dev'] + r['out_bytes_per_dev']) / 1e9:.1f}")


if __name__ == "__main__":
    main()
