"""Fig 26 — UDF complexity comparison (Q4-Q7) at 1X/4X/16X batches.

Paper claim reproduced: Tweet Context (Q6) does expensive ref-x-ref spatial
joins in its *state* build, so larger batches amortize it dramatically; the
probe-dominated UDFs (Q4/Q5/Q7) gain much less from batching."""

from __future__ import annotations

from benchmarks.common import (BATCH_1X, BATCH_4X, BATCH_16X, emit,
                               make_manager, run_feed)
from repro.core.enrich import queries as Q

FIG = "fig26"
UDFS = {"q4": Q.Q4, "q5": Q.Q5, "q6": Q.Q6, "q7": Q.Q7}


def main(total: int = 4_000, scale: float = 0.02) -> None:
    mgr = make_manager(scale=scale)
    for qname, udf in UDFS.items():
        for blabel, batch in (("1X", BATCH_1X), ("4X", BATCH_4X),
                              ("16X", BATCH_16X)):
            s = run_feed(mgr, f"f26-{qname}-{blabel}", total, batch,
                         udf=udf, framework="new", partitions=2)
            c = s.computing
            emit(FIG, f"{qname}_{blabel}_records_per_s", s.records_per_s,
                 "rec/s",
                 f"state_s={c.state_s:.2f} apply_s={c.apply_s:.2f} "
                 f"invocations={c.invocations}")


if __name__ == "__main__":
    main()
