"""Fig 25 — enrichment with UDFs Q1-Q4 (hash join / group-by / order-by /
spatial join) at 1X/4X/16X batches.

Configurations, mirroring the paper's:
  * new_sqlpp_*   — the new framework, Model 2 (state refreshed per batch),
                    jitted declarative UDFs (the paper's SQL++ case)
  * new_py_*      — same pipeline, but the UDF body is host-language python
                    per batch (the paper's Java-UDF analog)
  * current_noupd — coupled pipeline, Model 3: state built once, never
                    refreshed ("current w/o updates", the throughput ideal
                    that is blind to reference changes)
  * new_gated     — beyond-paper: version-gated Model 2 (Model-3 speed when
                    reference data is quiet, Model-2 freshness always)
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import (BATCH_1X, BATCH_4X, BATCH_16X, emit,
                               make_manager, run_feed)
from repro.core import ComputingRunner, ComputingSpec
from repro.core.enrich import queries as Q
from repro.core.records import SyntheticTweets, parse_json_lines

FIG = "fig25"
UDFS = {"q1": Q.Q1, "q2": Q.Q2, "q3": Q.Q3, "q4": Q.Q4}


# ---------------------------------------------------------------------------
# host-language ("Java") UDF bodies
# ---------------------------------------------------------------------------

def py_q1(batch, snap):
    a = snap["safety_levels"].arrays
    table = {int(k): int(v) for k, v in zip(a["key"], a["safety_level"])}
    return {"safety_level": np.asarray(
        [table.get(int(c), -1) for c in batch["country"]], np.int32)}


def py_q4(batch, snap):
    a = snap["monuments"].arrays
    pts = np.stack([a["lat"], a["lon"]], 1)
    out_ids, out_cnt = [], []
    for la, lo in zip(batch["lat"], batch["lon"]):
        d2 = (pts[:, 0] - la) ** 2 + (pts[:, 1] - lo) ** 2
        hits = np.where(d2 <= Q.Q4_RADIUS ** 2)[0]
        order = hits[np.argsort(d2[hits])][:Q.Q4_K]
        ids = np.full(Q.Q4_K, -1, np.int64)
        ids[:len(order)] = a["key"][order]
        out_ids.append(ids)
        out_cnt.append(len(hits))
    return {"nearby_monuments": np.stack(out_ids),
            "nearby_monument_count": np.asarray(out_cnt, np.int32)}


PY_UDFS = {"q1": ("safety_levels", py_q1), "q4": ("monuments", py_q4)}


def bench_python_udf(mgr, name, total, batch):
    table, fn = PY_UDFS[name]
    src = SyntheticTweets(seed=11)
    t0 = time.perf_counter()
    for frame in src.batches(total, batch):
        parsed = parse_json_lines(frame)
        snap = mgr.refstore.snapshot((table,))
        fn(parsed, snap)                      # state rebuilt per batch
    return total / (time.perf_counter() - t0)


def main(total: int = 8_000) -> None:
    mgr = make_manager(scale=0.02)
    batches = (("1X", BATCH_1X), ("4X", BATCH_4X), ("16X", BATCH_16X))

    for qname, udf in UDFS.items():
        for blabel, batch in batches:
            s = run_feed(mgr, f"f25-{qname}-{blabel}", total, batch,
                         udf=udf, framework="new", partitions=2)
            emit(FIG, f"{qname}_sqlpp_{blabel}", s.records_per_s, "rec/s",
                 f"state_builds={s.computing.state_builds}")
        # current w/o updates (Model 3, coupled)
        s = run_feed(mgr, f"f25-{qname}-noupd", total, BATCH_1X, udf=udf,
                     framework="balanced", partitions=2)
        emit(FIG, f"{qname}_current_noupd", s.records_per_s, "rec/s",
             "state built once; blind to reference updates")
        # beyond-paper: version-gated
        s = run_feed(mgr, f"f25-{qname}-gated", total, BATCH_1X, udf=udf,
                     framework="new", partitions=2, refresh="version")
        emit(FIG, f"{qname}_gated_1X", s.records_per_s, "rec/s",
             f"state_builds={s.computing.state_builds} (vs per-batch)")

    for qname in PY_UDFS:
        for blabel, batch in (("1X", BATCH_1X), ("16X", BATCH_16X)):
            rps = bench_python_udf(mgr, qname, min(total, 4000), batch)
            emit(FIG, f"{qname}_python_{blabel}", rps, "rec/s",
                 "host-language UDF (Java analog)")


if __name__ == "__main__":
    main()
