"""Fig 25 — enrichment with UDFs Q1-Q4 (hash join / group-by / order-by /
spatial join) at 1X/4X/16X batches.

Configurations, mirroring the paper's:
  * new_sqlpp_*   — the new framework, Model 2 (state refreshed per batch),
                    jitted declarative UDFs (the paper's SQL++ case)
  * new_py_*      — same pipeline, but the UDF body is host-language python
                    per batch (the paper's Java-UDF analog)
  * current_noupd — coupled pipeline, Model 3: state built once, never
                    refreshed ("current w/o updates", the throughput ideal
                    that is blind to reference changes)
  * new_gated     — beyond-paper: version-gated Model 2 (Model-3 speed when
                    reference data is quiet, Model-2 freshness always)

Dispatch axis (this repo, beyond the paper): ``--dispatch
{auto,reference,pallas}`` routes the enrichment operators through the
Pallas kernels or the jnp reference paths (core/enrich/dispatch.py), and
the ``hash_probe_1m`` section measures the raw equi-join probe at >= 1M
probe rows under the selected mode — the operator-level speedup the
framework-level numbers build on.  Off-TPU the pallas path is interpret-
mode emulation: expect it to LOSE there; the comparison is meaningful on
TPU hardware.

Plan axis (``--plan chained``): a fused Q1->Q2->Q3 ``IngestPlan`` (one
declarative pipeline, ONE predeployed apply per batch) vs. the same three
enrichments as three sequential single-UDF feeds — the chaining win the
plan API exists for.  Plus a sustained-backlog section measuring the
default-on worker coalescer (coalesce_rows auto vs 0) against a replayed
pre-generated stream, so intake always outruns computing.

Elastic axis (``--elastic``): a bursty square-wave stream (low/high rec/s
phases around the calibrated single-partition capacity) under static-low,
static-high, and controller-driven parallelism (core/elasticity.py) —
rec/s, p95 sampled backlog, and worker-seconds per config, plus the
elastic-vs-best-static ratio the acceptance criterion reads.

Feedscope axis (``--profile``): the full ops surface — trace spans,
journey profiling, SLO health, and the live endpoint scraped every 100ms
from another thread — A/B'd against a metrics-only feed (interleaved
medians, ``profile_overhead_ratio`` gated >= 0.97 in BOTH gate
profiles), plus a bottleneck-attribution ground-truth check: a tee sink
that sleeps 20ms/batch must be named by the profiler's ranked verdict
(hard assert on ``report.bottleneck == "sink.append"``).
"""

from __future__ import annotations

import argparse
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (BATCH_1X, BATCH_4X, BATCH_16X,
                               add_dispatch_arg, emit, make_manager,
                               run_feed, set_dispatch, write_json)
from repro.core import ElasticSpec, SyntheticAdapter, pipeline
from repro.core.enrich import dispatch as D
from repro.core.enrich import ops
from repro.core.intake import Adapter
from repro.core.obs import http_get
from repro.core.records import SyntheticTweets, parse_json_lines
from repro.core.refdata import KEY_SENTINEL
from repro.core.enrich import queries as Q

FIG = "fig25"
UDFS = {"q1": Q.Q1, "q2": Q.Q2, "q3": Q.Q3, "q4": Q.Q4}


# ---------------------------------------------------------------------------
# host-language ("Java") UDF bodies
# ---------------------------------------------------------------------------

def py_q1(batch, snap):
    a = snap["safety_levels"].arrays
    table = {int(k): int(v) for k, v in zip(a["key"], a["safety_level"])}
    return {"safety_level": np.asarray(
        [table.get(int(c), -1) for c in batch["country"]], np.int32)}


def py_q4(batch, snap):
    a = snap["monuments"].arrays
    pts = np.stack([a["lat"], a["lon"]], 1)
    out_ids, out_cnt = [], []
    for la, lo in zip(batch["lat"], batch["lon"]):
        d2 = (pts[:, 0] - la) ** 2 + (pts[:, 1] - lo) ** 2
        hits = np.where(d2 <= Q.Q4_RADIUS ** 2)[0]
        order = hits[np.argsort(d2[hits])][:Q.Q4_K]
        ids = np.full(Q.Q4_K, -1, np.int64)
        ids[:len(order)] = a["key"][order]
        out_ids.append(ids)
        out_cnt.append(len(hits))
    return {"nearby_monuments": np.stack(out_ids),
            "nearby_monument_count": np.asarray(out_cnt, np.int32)}


PY_UDFS = {"q1": ("safety_levels", py_q1), "q4": ("monuments", py_q4)}


def bench_python_udf(mgr, name, total, batch):
    table, fn = PY_UDFS[name]
    src = SyntheticTweets(seed=11)
    t0 = time.perf_counter()
    for frame in src.batches(total, batch):
        parsed = parse_json_lines(frame)
        snap = mgr.refstore.snapshot((table,))
        fn(parsed, snap)                      # state rebuilt per batch
    return total / (time.perf_counter() - t0)


def bench_hash_probe(nprobe: int, nref: int = 65_536, iters: int = 5,
                     seed: int = 17) -> float:
    """Raw sorted-join probe throughput (rows/s) under the active dispatch
    mode: the operator the paper's hash-join UDFs (Q1/Q5/Q6) bottleneck on.
    The probe batch is bucket-padded by the dispatch layer exactly as feed
    batches are, so this measures the production code path."""
    rng = np.random.default_rng(seed)
    keys = np.full((nref + 1024,), KEY_SENTINEL, np.int64)
    keys[:nref] = np.sort(rng.choice(nref * 4, nref, replace=False))
    ref_keys = jnp.asarray(keys)
    probe = jnp.asarray(rng.integers(0, nref * 4, nprobe).astype(np.int64))
    jitted = jax.jit(ops.sorted_join)
    out = jitted(probe, ref_keys)          # compile + warm
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jitted(probe, ref_keys)
    jax.block_until_ready(out)
    return nprobe * iters / (time.perf_counter() - t0)


class ReplayAdapter(Adapter):
    """Pre-generated frames, replayed at memory speed: intake always
    outruns computing, so the feed runs under sustained backlog (the
    regime the worker coalescer is for)."""

    def __init__(self, frames):
        super().__init__()
        self._frames = frames

    def frames(self):
        for f in self._frames:
            if self._stop.is_set():
                return
            yield f


def bench_chained_plan(mgr, total: int, batch: int = BATCH_1X) -> None:
    """--plan chained: fused Q1->Q2->Q3 IngestPlan vs three sequential
    single-UDF feeds over the same stream.  coalesce_rows=0 on BOTH sides:
    this axis isolates stage fusion; the coalescer (which would change
    each side's effective batch sizes under backlog) has its own A/B."""
    chain_udfs = {"q1": Q.Q1, "q2": Q.Q2, "q3": Q.Q3}
    seq_wall, seq_inv = 0.0, 0
    for qname, udf in chain_udfs.items():
        s = run_feed(mgr, f"f25-seq-{qname}", total, batch, udf=udf,
                     framework="new", partitions=2, coalesce_rows=0)
        seq_wall += s.wall_s
        seq_inv += s.computing.invocations
    emit(FIG, "chain_q123_sequential", total / seq_wall, "rec/s",
         f"3 single-UDF feeds back to back, invocations={seq_inv}")

    # ONE fused udf for both the warm and the timed plan: the predeploy
    # cache keys on function identity, so re-composing the chain per plan
    # would defeat the warm-up
    fused = Q.Q1.then(Q.Q2).then(Q.Q3)

    def chained_plan(name, n):
        return (pipeline(SyntheticAdapter(total=n, frame_size=batch,
                                          seed=11), name)
                .parse(batch_size=batch)
                .options(num_partitions=2, coalesce_rows=0)
                .enrich(fused)
                .store())

    # warm the fused apply executable: the sequential feeds above were
    # warmed by fig25's earlier sections, so without this the fused side
    # would be the only one paying a first compile inside the timed run
    mgr.submit(chained_plan("f25-chained-warm", 2 * batch)).join(
        timeout=1200)
    h = mgr.submit(chained_plan("f25-chained", total))
    s = h.join(timeout=1200)
    assert s.stored == total, (s.stored, total)
    builds = {name: st.state_builds
              for name, st in s.computing.per_stage.items()}
    emit(FIG, "chain_q123_fused", s.records_per_s, "rec/s",
         "1 fused plan (single predeployed apply/batch), "
         f"invocations={s.computing.invocations} vs sequential {seq_inv}; "
         f"per-stage state_builds={builds}")


class BurstyAdapter(Adapter):
    """Square-wave rate: pre-generated frames released at alternating
    low/high records-per-second phases — the load shape the elasticity
    controller exists for (ride the burst up, ride the quiet down)."""

    def __init__(self, frames, low_rate: float, high_rate: float,
                 period_s: float):
        super().__init__()
        self._frames = frames
        self.low, self.high, self.period = low_rate, high_rate, period_s

    def frames(self):
        t0 = time.perf_counter()
        vt = 0.0                       # virtual release clock
        for f in self._frames:
            if self._stop.is_set():
                return
            rate = self.high if int(vt / self.period) % 2 else self.low
            vt += len(f) / rate
            delay = t0 + vt - time.perf_counter()
            if delay > 0:
                time.sleep(delay)
            yield f


def bench_elastic(mgr, batch: int = BATCH_1X) -> None:
    """--elastic: a bursty (square-wave) stream under three parallelism
    policies — static low (1 partition), static high (4), and the
    elasticity controller (1..3, backlog-driven).  Reports rec/s, p95
    sampled backlog, and worker-seconds (live-worker integral: the cost an
    operator pays for headroom).  coalesce_rows=0 on every side so
    partition count is the only lever.  On this 2-core container thread
    parallelism is nearly flat (XLA CPU fans one dispatch over both
    cores), so the elastic win is on the COST axis: static-low throughput
    at a fraction of static-high's worker-seconds; on real multi-core
    nodes the throughput axis separates too."""
    # calibrate the single-partition steady-state capacity (warm + steady)
    cal = list(SyntheticTweets(seed=29).batches(24 * batch, batch))
    for name in ("elastic-cal-warm", "elastic-cal"):
        p = (pipeline(ReplayAdapter(cal), name).parse(batch_size=batch)
             .options(num_partitions=1, coalesce_rows=0, holder_capacity=16)
             .enrich(Q.Q1).store())
        s = mgr.submit(p).join(timeout=1200)
    cap = s.records_per_s
    emit(FIG, "bursty_capacity_1p", cap, "rec/s",
         "calibrated single-partition Q1 capacity for the square wave")

    # high phase overloads one partition by 1.2x, but the AVERAGE load
    # stays well under the scaled-up aggregate capacity — the burst's
    # backlog drains within each low phase, leaving an idle window, so the
    # controller must ride DOWN as well as up every cycle (sustained
    # overload, where staying scaled-up is the right call, is what the
    # coalescer A/B above measures)
    low, high, period, phases = 0.05 * cap, 1.2 * cap, 0.8, 8
    total = int(period * (phases / 2) * (low + high))
    total -= total % batch
    stream = list(SyntheticTweets(seed=37).batches(total, batch))

    configs = (
        ("static_lo", 1, ElasticSpec(min_partitions=1, max_partitions=1)),
        ("static_hi", 4, ElasticSpec(min_partitions=4, max_partitions=4)),
        ("elastic", 1, ElasticSpec(min_partitions=1, max_partitions=3,
                                   interval_s=0.02, high_watermark=1.0,
                                   low_watermark=1.5, up_after=2,
                                   down_after=6, cooldown_s=0.15)),
    )
    results = {}
    for label, n, spec in configs:
        p = (pipeline(BurstyAdapter(stream, low, high, period),
                      f"bursty-{label}")
             .parse(batch_size=batch)
             .options(num_partitions=n, coalesce_rows=0,
                      holder_capacity=16, elastic=spec)
             .enrich(Q.Q1).store())
        s = mgr.submit(p).join(timeout=1200)
        assert s.stored == total, (label, s.stored, total)
        results[label] = s
        peak = s.peak_partitions.get("q1_safety_level", n)
        emit(FIG, f"bursty_{label}", s.records_per_s, "rec/s",
             f"square wave {low:.0f}/{high:.0f} rec/s x{total} rows; "
             f"p95_backlog={s.backlog_p95_rows:.0f} rows "
             f"worker_s={s.worker_seconds:.2f} "
             f"scale_ups={s.scale_ups} scale_downs={s.scale_downs} "
             f"peak_partitions={peak}")
    best_static = max(results["static_lo"].records_per_s,
                      results["static_hi"].records_per_s)
    e = results["elastic"]
    emit(FIG, "bursty_elastic_vs_best_static",
         e.records_per_s / best_static, "ratio",
         "acceptance: >= 0.9 of best static AND "
         f"worker_s {e.worker_seconds:.2f} < static_hi "
         f"{results['static_hi'].worker_seconds:.2f}")


def bench_backlog_coalescing(mgr, total: int, batch: int = BATCH_1X
                             ) -> None:
    """Default-on coalescer under sustained backlog: auto (4x batch) vs
    off, same pre-generated stream (before/after for CHANGES.md).  Two
    passes per config; the first warms the predeploy cache (the auto path
    compiles two extra bucket shapes, 2x/4x batch) and the second is the
    emitted steady-state number."""
    bl_total = max(total, 60_000)
    src = SyntheticTweets(seed=23)
    frames = list(src.batches(bl_total, batch))
    for label, coal in (("off", 0), ("auto", None)):
        for rnd in ("warmup", "steady"):
            p = (pipeline(ReplayAdapter(frames),
                          f"f25-backlog-{label}-{rnd}")
                 .parse(batch_size=batch)
                 .options(num_partitions=2, coalesce_rows=coal,
                          holder_capacity=32)
                 .enrich(Q.Q1).store())
            s = mgr.submit(p).join(timeout=1200)
            assert s.stored == bl_total, (s.stored, bl_total)
        emit(FIG, f"backlog_coalesce_{label}", s.records_per_s, "rec/s",
             f"replayed stream x{bl_total} rows, warm predeploy; "
             f"invocations={s.computing.invocations} "
             f"coalesced_frames={s.coalesced_frames}")


def bench_obs_overhead(mgr, total: int, batch: int = BATCH_1X) -> None:
    """Observability overhead gate: the SAME replayed stream through an
    untraced feed (metrics registry only — always on) and a traced one
    (``options(trace=...)``: span stamping at every hop, per-thread
    rings).  Interleaved rounds with per-side medians (the fig_repair
    interference pattern, so drift hits both sides equally); the
    regression gate holds traced/untraced to >= 0.97.  The gated ratio
    is the median of per-round ADJACENT-PAIR ratios, not the ratio of
    per-side medians: a noisy-neighbor window a few seconds long covers
    whole off/on pairs and cancels out of their ratio, where it would
    skew whichever side's median caught more of it."""
    n = max(total, 12_000)
    n -= n % batch
    frames = list(SyntheticTweets(seed=41).batches(n, batch))

    def run(label, rnd, trace):
        opts = dict(num_partitions=2, coalesce_rows=0, holder_capacity=32)
        if trace:
            opts["trace"] = {"capacity": 4096}
        p = (pipeline(ReplayAdapter(frames), f"f25-obs-{label}-{rnd}")
             .parse(batch_size=batch)
             .options(**opts)
             .enrich(Q.Q1).store())
        s = mgr.submit(p).join(timeout=1200)
        assert s.stored == n, (s.stored, n)
        return s.records_per_s

    run("off", "warm", False)        # warm the predeploy cache once
    run("on", "warm", True)
    off, on = [], []
    for rnd in range(5):
        off.append(run("off", rnd, False))
        on.append(run("on", rnd, True))
    m_off = sorted(off)[len(off) // 2]
    m_on = sorted(on)[len(on) // 2]
    ratios = sorted(b / a for a, b in zip(off, on))
    emit(FIG, "obs_off", m_off, "rec/s",
         f"median of {len(off)} interleaved rounds x{n} rows, "
         "metrics only")
    emit(FIG, "obs_on", m_on, "rec/s",
         "same replayed stream, trace spans enabled")
    emit(FIG, "obs_overhead_ratio", ratios[len(ratios) // 2], "ratio",
         "median of per-round paired ratios; acceptance: >= 0.97 "
         "(tracing must stay ~free)")


def bench_profile_overhead(total: int, batch: int = BATCH_1X) -> None:
    """--profile: the full feedscope surface under active use — trace
    spans, journey profiling, SLO health, AND the live ops endpoint
    being scraped from another thread while the feed runs — against the
    metrics-only baseline.  Same interleaved protocol and paired-ratio
    statistic as the trace-only A/B above; the gate holds profiled/bare
    to >= 0.97, so turning the whole ops surface on must stay ~free on
    the hot path.

    A FRESH manager isolates the A/B: ``/metrics`` renders every feed
    the manager has ever run, so piggybacking on the main manager would
    bill the profiled side for rendering dozens of *finished* feeds
    from earlier sections.  Each profiled round is scraped exactly ONCE,
    mid-run (every route, deterministic — no per-round scrape-count
    luck); these runs last well under a second, so even one scrape per
    run is an order of magnitude more scraping per unit work than a
    production Prometheus cadence (15s) would ever apply, and the
    parse path the scrape's GIL time steals from is the benchmark's
    bottleneck — a conservative measurement, not a softball."""
    mgr = make_manager(scale=0.02)
    n = max(2 * total, 24_000)
    n -= n % batch
    frames = list(SyntheticTweets(seed=43).batches(n, batch))

    def run(label, rnd, profiled):
        opts = dict(num_partitions=2, coalesce_rows=0, holder_capacity=32)
        if profiled:
            opts.update(trace={"capacity": 4096}, profile=True,
                        health=True)
        p = (pipeline(ReplayAdapter(frames), f"f25-prof-{label}-{rnd}")
             .parse(batch_size=batch)
             .options(**opts)
             .enrich(Q.Q1).store())
        h = mgr.submit(p)
        stop = threading.Event()
        scraper = None
        if profiled:
            url = mgr.serve_obs(port=0).url
            # one operator scrape, fired mid-run: every endpoint the
            # dashboard would poll, concurrent with ingestion — the
            # profiled side pays for rendering too, not just stamping
            def scrape():
                stop.wait(0.1)
                for route in ("/metrics", "/profile", "/health"):
                    status, _ = http_get(url + route)
                    assert status in (200, 503), (route, status)
            scraper = threading.Thread(target=scrape, daemon=True,
                                       name="f25-scraper")
            scraper.start()
        try:
            s = h.join(timeout=1200)
        finally:
            stop.set()
            if scraper is not None:
                scraper.join(timeout=30)
        assert s.stored == n, (s.stored, n)
        if profiled:
            rep = h.profile()
            assert rep is not None and rep.journeys > 0, rep
        return s.records_per_s

    run("off", "warm", False)        # warm the predeploy cache once
    run("on", "warm", True)
    off, on = [], []
    for rnd in range(5):
        off.append(run("off", rnd, False))
        on.append(run("on", rnd, True))
    mgr.stop_obs()
    m_off = sorted(off)[len(off) // 2]
    m_on = sorted(on)[len(on) // 2]
    ratios = sorted(b / a for a, b in zip(off, on))
    emit(FIG, "profile_off", m_off, "rec/s",
         f"median of {len(off)} interleaved rounds x{n} rows, "
         "metrics only")
    emit(FIG, "profile_on", m_on, "rec/s",
         "trace + journey profiler + health + live endpoint, every "
         "route scraped once mid-run")
    emit(FIG, "profile_overhead_ratio", ratios[len(ratios) // 2],
         "ratio", "median of per-round paired ratios; acceptance: "
         ">= 0.97 (the whole ops surface must stay ~free)")


def bench_profile_bottleneck(mgr, batch: int = BATCH_1X) -> None:
    """--profile: bottleneck-attribution ground truth.  Inject a known
    slow hop — a tee sink that sleeps 60ms per batch, several times the
    worst contended Q1 apply — and hard-assert the profiler's ranked
    verdict names it.  Two details keep the ground truth unambiguous on
    a loaded CI core: frames arrive PACED at ~30ms/batch (BurstyAdapter
    with low == high), so backlog pools at the tee and only the tee (a
    memory-speed replay parks every frame in the intake holder at t=0
    and the wait bills to the apply hop's queue time instead); and a
    tiny warm feed runs first, because the one-time jit compile
    otherwise rides as apply-queue time in EVERY journey (the compile
    happens while all of them sit in the intake holder)."""
    nb = 16
    total = nb * batch
    stream = list(SyntheticTweets(seed=47).batches(total, batch))

    wp = (pipeline(ReplayAdapter(stream[:2]), "f25-prof-slowtee-warm")
          .parse(batch_size=batch)
          .options(num_partitions=1, coalesce_rows=0)
          .enrich(Q.Q1).store())
    mgr.submit(wp).join(timeout=1200)

    def slow_tee(b):
        time.sleep(0.06)

    rate = batch / 0.03
    p = (pipeline(BurstyAdapter(stream, rate, rate, 1.0),
                  "f25-prof-slowtee")
         .parse(batch_size=batch)
         .options(num_partitions=1, coalesce_rows=0, holder_capacity=64,
                  profile=True)
         .enrich(Q.Q1)
         .tee(slow_tee, name="lagmirror")
         .store())
    h = mgr.submit(p)
    s = h.join(timeout=1200)
    assert s.stored == total, (s.stored, total)
    rep = h.profile()
    assert rep is not None and rep.journeys > 0, rep
    assert rep.bottleneck == "sink.append", rep.ranked[:3]
    frac = dict(rep.ranked)["sink.append"]
    emit(FIG, "profile_bottleneck_sink_frac", frac, "frac",
         "injected 60ms/batch tee at a 30ms/batch arrival pace: the "
         "verdict must (and did) name sink.append; runner-up "
         f"{rep.ranked[1] if len(rep.ranked) > 1 else None}")


def main(total: int = 8_000, dispatch: str = "auto",
         probe_rows: int = 1_000_000, plan: str = "chained",
         elastic: bool = False, profile: bool = False) -> None:
    set_dispatch(dispatch)
    tag = f"[dispatch={dispatch}]"

    # off-TPU the pallas path is interpret-mode emulation (~1000x slower):
    # cap the microbench so --dispatch pallas still completes end-to-end;
    # the row count is in the emitted name, so runs stay comparable
    if dispatch == "pallas" and jax.default_backend() != "tpu":
        capped = min(probe_rows, 32_768)
        if capped != probe_rows:
            emit(FIG, "hash_probe_note", capped, "rows",
                 f"{tag} interpret-mode emulation off-TPU: probe rows "
                 f"capped from {probe_rows}")
        probe_rows = capped

    rps = bench_hash_probe(probe_rows)
    emit(FIG, f"hash_probe_{probe_rows}", rps, "rows/s",
         f"{tag} sorted-join probe, nref=65536, "
         f"buckets={sorted(set(b for _, b in D.bucket_stats()))}")

    mgr = make_manager(scale=0.02)
    batches = (("1X", BATCH_1X), ("4X", BATCH_4X), ("16X", BATCH_16X))

    for qname, udf in UDFS.items():
        for blabel, batch in batches:
            # coalesce_rows=0: this sweep IS the paper's batch-size axis —
            # the (default-on) backlog coalescer would silently turn the
            # 1X point into ~4X batches; the coalescer gets its own
            # dedicated A/B below (backlog_coalesce_{off,auto})
            s = run_feed(mgr, f"f25-{qname}-{blabel}", total, batch,
                         udf=udf, framework="new", partitions=2,
                         coalesce_rows=0)
            emit(FIG, f"{qname}_sqlpp_{blabel}", s.records_per_s, "rec/s",
                 f"state_builds={s.computing.state_builds}")
        # current w/o updates (Model 3, coupled)
        s = run_feed(mgr, f"f25-{qname}-noupd", total, BATCH_1X, udf=udf,
                     framework="balanced", partitions=2)
        emit(FIG, f"{qname}_current_noupd", s.records_per_s, "rec/s",
             "state built once; blind to reference updates")
        # beyond-paper: version-gated
        s = run_feed(mgr, f"f25-{qname}-gated", total, BATCH_1X, udf=udf,
                     framework="new", partitions=2, refresh="version",
                     coalesce_rows=0)
        emit(FIG, f"{qname}_gated_1X", s.records_per_s, "rec/s",
             f"state_builds={s.computing.state_builds} (vs per-batch)")
        # beyond-paper: worker micro-batching (coalesce backlog into one
        # kernel dispatch, bucket-padded — see core/feed.py)
        s = run_feed(mgr, f"f25-{qname}-coal", total, BATCH_1X, udf=udf,
                     framework="new", partitions=2,
                     coalesce_rows=BATCH_16X)
        emit(FIG, f"{qname}_coalesced_1X", s.records_per_s, "rec/s",
             f"coalesced_frames={s.coalesced_frames} "
             f"invocations={s.computing.invocations}")

    for qname in PY_UDFS:
        for blabel, batch in (("1X", BATCH_1X), ("16X", BATCH_16X)):
            rps = bench_python_udf(mgr, qname, min(total, 4000), batch)
            emit(FIG, f"{qname}_python_{blabel}", rps, "rec/s",
                 "host-language UDF (Java analog)")

    if plan == "chained":
        bench_chained_plan(mgr, total)
        bench_backlog_coalescing(mgr, total)
    if elastic:
        bench_elastic(mgr)
    # unconditional: the obs on/off ratio gates EVERY profile (smoke
    # included) — observability that taxes the hot path is a regression
    bench_obs_overhead(mgr, total)
    if profile:
        bench_profile_overhead(total)
        bench_profile_bottleneck(mgr)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    add_dispatch_arg(ap)
    ap.add_argument("--total", type=int, default=8_000)
    ap.add_argument("--probe-rows", type=int, default=1_000_000,
                    help="hash-probe microbench probe rows (>= 1M for the "
                         "paper-scale measurement)")
    ap.add_argument("--plan", choices=["none", "chained"],
                    default="chained",
                    help="chained: fused Q1->Q2->Q3 IngestPlan vs three "
                         "sequential feeds + backlog-coalescing A/B")
    ap.add_argument("--elastic", action="store_true",
                    help="bursty square-wave stream: static low/high "
                         "partitions vs the elasticity controller "
                         "(rec/s, p95 backlog, worker-seconds)")
    ap.add_argument("--profile", action="store_true",
                    help="feedscope axis: full ops surface (trace + "
                         "journey profiler + health + scraped live "
                         "endpoint) vs metrics-only A/B, plus the "
                         "injected-slow-tee bottleneck-verdict check")
    ap.add_argument("--json-out", default="BENCH_fig25.json",
                    help="machine-readable metrics file "
                         "(empty string disables)")
    args = ap.parse_args()
    main(args.total, args.dispatch, args.probe_rows, args.plan,
         args.elastic, args.profile)
    if args.json_out:
        write_json(FIG, args.json_out)
