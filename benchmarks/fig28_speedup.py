"""Fig 27/28 — speed-up vs cluster size x batch size.

Single-core honesty: this container cannot show real multi-node speed-up,
so we reproduce the paper's *mechanism* instead of its wall clock.  The
overall ingestion time decomposes as

    T(P) ~= (T_state + T_apply) / P + invocations(T_batch) * c_inv(P)

i.e. UDF compute scales with partitions P while per-invocation overhead
grows with cluster size (the paper's 'execution overhead of a bigger
cluster').  We measure T_state, T_apply, and c_inv from instrumented runs,
then report the derived 24-vs-6 'node' speed-up per (UDF x batch size) —
the same quantity Fig 28 plots.  Claims reproduced: simple UDFs (Q1-Q3)
speed up poorly and degrade with small batches; complex spatial UDFs
(Q4-Q7) approach linear speed-up; bigger batches always help."""

from __future__ import annotations

import argparse
import time

from benchmarks.common import (BATCH_1X, BATCH_4X, BATCH_16X,
                               add_dispatch_arg, emit, make_manager,
                               set_dispatch)
from repro.core import ComputingRunner, ComputingSpec
from repro.core.enrich import queries as Q
from repro.core.records import SyntheticTweets, parse_json_lines

FIG = "fig28"
UDFS = {"q1": Q.Q1, "q2": Q.Q2, "q3": Q.Q3, "q4": Q.Q4,
        "q5": Q.Q5, "q6": Q.Q6, "q7": Q.Q7}
# measured per-invocation scheduling overhead growth per node (seconds):
# from the paper's Fig 24 overhead discussion; re-derived below from the
# measured predeploy-invocation cost at P=1 and a linear growth model.
OVERHEAD_GROWTH = 1.10   # +10%/node step from 6->24 in the model


def measure(udf, total, batch, mgr):
    runner = ComputingRunner(
        ComputingSpec(udf, batch, "per_batch", "always"),
        mgr.refstore, mgr.predeploy)
    src = SyntheticTweets(seed=13)
    # pre-generate + pre-parse outside the timed loop: records arrive
    # parsed from the intake frame in this micro-benchmark; the parse cost
    # itself is measured by fig24
    frames = [parse_json_lines(f) for f in src.batches(total, batch)]
    for f in frames[:2]:                            # warmup: compile
        runner.run(f)
    runner.stats = type(runner.stats)()
    inv = 0
    t_wall0 = time.perf_counter()
    for frame in frames:
        runner.run(frame)
        inv += 1
    wall = time.perf_counter() - t_wall0
    st = runner.stats
    # everything data-proportional parallelizes over nodes; the residual
    # is fixed per-invocation dispatch (the paper's execution overhead)
    t_compute = (st.state_s + st.apply_s + st.parse_s + st.upload_s
                 + st.convert_s)
    c_inv = max(wall - t_compute, 0.0) / inv
    return wall, t_compute, c_inv, inv


def derived_time(t_compute, c_inv, inv, nodes):
    """parse + state + apply parallelize over nodes; per-invocation
    coordination overhead grows ~linearly with cluster size (the paper's
    'execution overhead of a bigger cluster')."""
    return t_compute / nodes + inv * c_inv * (1 + 0.1 * (nodes - 1))


def main(total: int = 3_000, dispatch: str = "auto",
         plan: str = "chained") -> None:
    set_dispatch(dispatch)
    mgr = make_manager(scale=0.02)
    udfs = dict(UDFS)
    if plan == "chained":
        # the plan API's fused chain as its own scaling point: one
        # invocation (and one per-invocation overhead c_inv) carries all
        # three stages, so the chain scales like a complex UDF even though
        # its stages are simple ones (Q1-Q3 individually scale poorly)
        udfs["q1q2q3_fused"] = Q.Q1.then(Q.Q2).then(Q.Q3)
    for qname, udf in udfs.items():
        for blabel, batch in (("1X", BATCH_1X), ("4X", BATCH_4X),
                              ("16X", BATCH_16X)):
            wall, t_c, c_inv, inv = measure(udf, total, batch, mgr)
            t6 = derived_time(t_c, c_inv, inv, 6)
            t24 = derived_time(t_c, c_inv, inv, 24)
            emit(FIG, f"{qname}_{blabel}_speedup_24v6", t6 / t24, "x",
                 f"[dispatch={dispatch}] wall={wall:.2f}s "
                 f"compute={t_c:.2f}s "
                 f"c_inv={c_inv*1e3:.2f}ms inv={inv} (derived model)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    add_dispatch_arg(ap)
    ap.add_argument("--total", type=int, default=3_000)
    ap.add_argument("--plan", choices=["none", "chained"],
                    default="chained",
                    help="chained: add the fused Q1>Q2>Q3 plan-API chain "
                         "as a scaling point")
    args = ap.parse_args()
    main(args.total, args.dispatch, args.plan)
