"""fig_repair — freshness vs throughput for progressive re-enrichment
(core/repair.py), beyond the paper: the paper's Model 2 keeps *in-flight*
batches fresh; this axis measures keeping the *stored* dataset fresh while
a feed ingests under a rolling reference-update workload.

Sections:

  currency      a throttled stream (~0.7x the calibrated single-partition
                Q1 capacity) ingests while a rolling updater upserts
                existing safety_levels keys; the repair scheduler
                interleaves with ingestion inside its row budget.  Emits
                repair_lag p50/p95 (ref upsert -> repaired row), stale /
                repaired / refined row counts, and a convergence check:
                after join() every stored row must equal a from-scratch
                enrichment under the final snapshot (mismatches must be 0).

  interference  an unthrottled replayed stream (sustained backlog — the
                worst case for a background job) with the same rolling
                updates, repair OFF vs ON at the configured budget.  The
                emitted ratio is ingest-side rec/s (post-feed repair drain
                excluded); acceptance: >= 0.9, i.e. the default
                ``budget_rows_s`` + backlog yielding bound repair's
                ingestion interference to <= 10%.
"""

from __future__ import annotations

import argparse
import threading
import time

import numpy as np

from benchmarks.common import (BATCH_1X, emit, make_manager,
                               write_json)
from benchmarks.fig25_udf_enrichment import ReplayAdapter
from repro.core import RepairSpec, SyntheticAdapter, pipeline
from repro.core.enrich import queries as Q
from repro.core.records import SyntheticTweets

FIG = "fig_repair"


class RollingUpdater(threading.Thread):
    """Upserts ``nkeys`` random existing safety_levels keys every
    ``every_s`` until stopped — the rolling reference-update workload."""

    def __init__(self, table, nbase: int, every_s: float, nkeys: int,
                 seed: int = 5):
        super().__init__(name="rolling-updater", daemon=True)
        self.table, self.nbase = table, nbase
        self.every_s, self.nkeys = every_s, nkeys
        self.rng = np.random.default_rng(seed)
        self.updates = 0
        self._stop_evt = threading.Event()

    def run(self) -> None:
        while not self._stop_evt.wait(self.every_s):
            keys = self.rng.choice(self.nbase, self.nkeys, replace=False)
            self.table.upsert(keys.astype(np.int64),
                              safety_level=self.rng.integers(
                                  0, 5, self.nkeys).astype(np.int32))
            self.updates += 1

    def stop(self) -> None:
        self._stop_evt.set()


def join_quiesced(h, upd, timeout=1200):
    """Wait for the intake to finish, STOP the rolling updater, then
    join().  join() drains repair to convergence — a target that keeps
    moving while the updater runs, so the update workload must quiesce
    once ingestion (the thing being measured) is done."""
    while h.intake is not None and h.intake.is_alive():
        time.sleep(0.02)
    upd.stop()
    upd.join(timeout=10)
    return h.join(timeout=timeout)


def q1_plan(adapter, name: str, batch: int, refresh=None):
    return (pipeline(adapter, name)
            .parse(batch_size=batch)
            .options(num_partitions=2, coalesce_rows=0, holder_capacity=16)
            .enrich(Q.Q1)
            .store(refresh=refresh))


def check_convergence(mgr, storage) -> int:
    """#stored rows differing from a from-scratch enrichment under the
    final reference snapshot (repair converged <=> 0)."""
    snap = mgr.refstore["safety_levels"].snapshot()
    a = snap.arrays
    table = {int(k): int(v) for k, v in
             zip(a["key"][:snap.size], a["safety_level"][:snap.size])}
    bad = 0
    rows = {}
    for c in storage.scan():            # latest occurrence wins (row order)
        for i in range(c["id"].shape[0]):
            rows[int(c["id"][i])] = (int(c["country"][i]),
                                     int(c["safety_level"][i]))
    for country, lvl in rows.values():
        if lvl != table.get(country, -1):
            bad += 1
    return bad


def bench_currency(mgr, nbase: int, total: int, batch: int,
                   budget: float, update_every_s: float,
                   update_keys: int) -> None:
    # calibrate the unthrottled capacity so the throttled rate leaves the
    # repair scheduler real idle windows to interleave into
    for name in ("cal-warm", "cal"):
        h = mgr.submit(q1_plan(
            SyntheticAdapter(total=max(total // 2, 4 * batch),
                             frame_size=batch, seed=11), name, batch))
        s = h.join(timeout=1200)
    cap = s.records_per_s
    emit(FIG, "capacity_2p", cap, "rec/s",
         "calibrated unthrottled Q1 capacity (2 partitions)")

    upd = RollingUpdater(mgr.refstore["safety_levels"], nbase,
                         update_every_s, update_keys)
    h = mgr.submit(q1_plan(
        SyntheticAdapter(total=total, frame_size=batch, seed=13,
                         rate=0.7 * cap), "currency", batch,
        refresh=RepairSpec(budget_rows_s=budget)))
    upd.start()
    s = join_quiesced(h, upd)
    assert s.stored == total, (s.stored, total)
    r = s.repair
    emit(FIG, "currency_repair_lag_p50", s.repair_lag_p50_s, "s",
         f"rolling updates: {upd.updates} upserts of {update_keys} keys "
         f"every {update_every_s}s during ingest @0.7x capacity")
    emit(FIG, "currency_repair_lag_p95", s.repair_lag_p95_s, "s",
         f"budget_rows_s={budget:.0f} drain_s={s.repair_drain_s:.3f}")
    emit(FIG, "currency_stale_rows", s.stale_rows, "rows",
         f"repaired={s.repaired_rows} refined={r.refined_rows} "
         f"superseded={r.superseded_rows} yields={r.yields} "
         f"invocations={r.repair_invocations}")
    # the SAME currency numbers through the unified metrics registry:
    # RepairStats.add_lag dual-writes its sample ring and the native
    # repair_currency_s histogram, so the registry percentiles must
    # agree with the stats-computed ones (within 10% — both retain the
    # newest ~4K samples, but halve at different ring positions)
    m = h.metrics()
    cur = m["repair_currency_s"]
    emit(FIG, "currency_registry_lag_p50", cur.percentile(0.5), "s",
         f"handle.metrics()['repair_currency_s'], {cur.count} samples")
    emit(FIG, "currency_registry_lag_p95", cur.percentile(0.95), "s",
         "native histogram percentile (exposition-ready)")
    for q, stat in ((0.5, s.repair_lag_p50_s), (0.95, s.repair_lag_p95_s)):
        reg_v = cur.percentile(q)
        if stat > 1e-9:
            assert abs(reg_v - stat) <= 0.1 * stat, (q, reg_v, stat)
    lat = m["ingest_visible_latency_s"]
    emit(FIG, "currency_visible_latency_p95", lat.percentile(0.95), "s",
         f"intake stamp -> store-queryable, {lat.count} batches")
    mismatches = check_convergence(mgr, h.storage)
    emit(FIG, "currency_converged_mismatches", mismatches, "rows",
         "stored vs from-scratch enrichment under the final snapshot "
         f"over {h.storage.count} rows (must be 0)")
    assert mismatches == 0, mismatches


def bench_interference(mgr, nbase: int, total: int, batch: int,
                       budget: float, update_every_s: float,
                       update_keys: int) -> None:
    frames = list(SyntheticTweets(seed=17).batches(total, batch))
    configs = (("off", None), ("on", RepairSpec(budget_rows_s=budget)))
    samples = {"off": [], "on": []}
    last = {}
    # rounds interleave off/on so slow system drift (thermal, page cache,
    # XLA autotuning) hits both sides equally; the emitted number is the
    # per-side MEDIAN of the steady rounds
    for rnd in ("warmup", "steady1", "steady2", "steady3"):
        for label, refresh in configs:
            upd = RollingUpdater(mgr.refstore["safety_levels"], nbase,
                                 update_every_s, update_keys,
                                 seed=19)
            upd.start()
            h = mgr.submit(q1_plan(ReplayAdapter(frames),
                                   f"intf-{label}-{rnd}", batch,
                                   refresh=refresh))
            s = join_quiesced(h, upd)
            assert s.stored == total, (label, s.stored, total)
            if rnd == "warmup":
                continue
            ingest_s = s.wall_s - s.repair_drain_s
            samples[label].append(s.records_in / ingest_s
                                  if ingest_s else 0.0)
            last[label] = s
    results = {}
    for label, _ in configs:
        xs = sorted(samples[label])
        results[label] = xs[len(xs) // 2]
        s = last[label]
        extra = ""
        if s.repair is not None:
            extra = (f" repaired={s.repaired_rows} "
                     f"yields={s.repair.yields} "
                     f"drain_s={s.repair_drain_s:.3f}")
        emit(FIG, f"interference_repair_{label}", results[label], "rec/s",
             f"unthrottled replay x{total} rows, median of "
             f"{len(xs)} interleaved steady rounds, ingest-side (drain "
             f"excluded), rolling updates on;{extra}")
    emit(FIG, "interference_ratio", results["on"] / results["off"],
         "ratio",
         "acceptance: >= 0.9 (<= 10% ingestion-throughput loss at "
         f"budget_rows_s={budget:.0f})")


def main(total: int = 40_000, batch: int = BATCH_1X,
         budget: float = 10_000.0, update_every_s: float = 0.1,
         update_keys: int = 25) -> None:
    mgr = make_manager(scale=0.02)
    nbase = len(mgr.refstore["safety_levels"])
    update_keys = min(update_keys, nbase)
    bench_currency(mgr, nbase, total, batch, budget, update_every_s,
                   update_keys)
    # the interference A/B needs longer runs than the currency section:
    # each steady round is one wall-clock sample and the ratio divides two
    bench_interference(mgr, nbase, 2 * total, batch, budget,
                       update_every_s, update_keys)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--total", type=int, default=40_000)
    ap.add_argument("--batch", type=int, default=BATCH_1X)
    ap.add_argument("--budget", type=float, default=10_000.0,
                    help="RepairSpec.budget_rows_s (scanned rows/s)")
    ap.add_argument("--update-every", type=float, default=0.1,
                    help="seconds between rolling ref upserts")
    ap.add_argument("--update-keys", type=int, default=25,
                    help="keys upserted per rolling update")
    ap.add_argument("--json-out", default="BENCH_fig_repair.json",
                    help="machine-readable metrics file "
                         "(empty string disables)")
    args = ap.parse_args()
    main(args.total, args.batch, args.budget, args.update_every,
         args.update_keys)
    if args.json_out:
        write_json(FIG, args.json_out)
