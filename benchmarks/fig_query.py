"""fig_query — the analytical read side (core/query.py), beyond the
paper's figures: the paper pushes enrichment into ingestion precisely so
results can be "stored (and queried) together with the data"; this axis
measures that query side over the enriched column store.

Sections:

  scan_pruning   a flushed store is scanned with a selective id-range
                 predicate + group-by aggregation, zone-map pruning ON vs
                 OFF (identical snapshots, results asserted bitwise
                 equal).  Emits dataset-coverage throughput (snapshot
                 rows / query wall) per side and the on/off ratio —
                 acceptance at full scale: >= 2x.

  under_ingest   queries run in a loop WHILE a throttled feed ingests and
                 the repair scheduler re-enriches under rolling reference
                 updates: per-query latency p50/p95, visibility lag
                 (rows ingested vs rows visible in the query's snapshot),
                 and consistency checks every pass (pruned == unpruned on
                 the same snapshot; live counts monotone; at smoke scale
                 a naive python full-scan must match bitwise).

  compaction     a repair-churned store accumulates superseded versions;
                 full-scan aggregation throughput is measured before and
                 after draining the compaction job.  Acceptance: 100% of
                 dead rows reclaimed, identical query results, and a
                 smaller scanned-row footprint after.

  merged_read    the read-path overhaul A/B (``--merge`` x
                 ``--batched-agg``): a store flushed at 2K-row segments
                 is queried with a selective predicate + group-by
                 aggregation four ways — eager per-unit aggregation over
                 the unmerged layout (the pre-merge read path), the
                 one-dispatch batched path, then both again after
                 ``merge_now`` folds the small segments into leveled
                 runs.  Results asserted bitwise identical on every
                 side.  Acceptance at full scale: merged + batched
                 >= 1.5x the unmerged eager path.

Every section asserts its internal invariants, so the bench-smoke CI job
(tiny row counts) exercises the real driver end to end.
"""

from __future__ import annotations

import argparse
import shutil
import tempfile
import time

import numpy as np

from benchmarks.common import (BATCH_1X, emit, make_manager,
                               write_json)
from benchmarks.fig_repair import RollingUpdater, join_quiesced
from repro.core import (CompactionSpec, RepairSpec, SyntheticAdapter, agg,
                        col, pipeline)
from repro.core.enrich import queries as Q

FIG = "fig_query"


def q1_store_plan(adapter, name, batch, spill_dir=None, segment_rows=5000,
                  refresh=None, compact=None, upsert=True):
    return (pipeline(adapter, name)
            .parse(batch_size=batch)
            .options(num_partitions=2, coalesce_rows=0, holder_capacity=16)
            .enrich(Q.Q1)
            .store(spill_dir=spill_dir, segment_rows=segment_rows,
                   refresh=refresh, compact=compact, upsert=upsert))


def _median(xs):
    xs = sorted(xs)
    return xs[len(xs) // 2]


def group_query(handle):
    return (handle.query().where(col("safety_level") >= 0)
            .group_by("safety_level").agg(n=agg.count()))


def naive_check(storage, pred_col, threshold):
    """Smoke-scale bitwise oracle: python full scan on the same snapshot."""
    from repro.core import StoreSnapshot
    with StoreSnapshot(storage) as snap:
        got = (storage.query().where(col(pred_col) >= threshold)
               .group_by("safety_level").agg(n=agg.count())
               .execute(snapshot=snap))
        want = {}
        for ps in snap.parts:
            for u in ps.units:
                cols = u.read((pred_col, "safety_level", "id"))
                if u.rows == 0:
                    continue
                live = ps.live_mask(cols["id"], u.base)
                sel = live & (cols[pred_col] >= threshold)
                for lvl in np.asarray(cols["safety_level"])[sel]:
                    want[int(lvl)] = want.get(int(lvl), 0) + 1
    keys = sorted(want)
    assert got["safety_level"].tolist() == keys, (got, want)
    assert got["n"].tolist() == [want[k] for k in keys], (got, want)


def bench_scan_pruning(mgr, total, batch, spill_dir, reps=7):
    # ~12 flushed segments per partition at every scale, so the smoke run
    # exercises real pruning too
    h = mgr.submit(q1_store_plan(
        SyntheticAdapter(total=total, frame_size=batch, seed=11),
        "qp-fill", batch, spill_dir=spill_dir,
        segment_rows=max(total // 24, 100)))
    s = h.join(timeout=1200)
    assert s.stored == total, (s.stored, total)
    h.storage.flush()

    # ids ascend with arrival, so an id-range predicate is the natural
    # zone-map-prunable selective scan (first 2% of the stream)
    pred = col("id") < max(int(total * 0.02), 1)
    q = (h.query().where(pred).group_by("safety_level")
         .agg(n=agg.count(), top=agg.topk("safety_level", 3)))
    walls = {True: [], False: []}
    results = {}
    for rep in range(reps):
        for prune in (True, False):
            r = q.execute(prune=prune)
            walls[prune].append(r.stats.wall_s)
            results[prune] = r
    r_on, r_off = results[True], results[False]
    for k in r_on:                       # acceptance: bitwise identical
        np.testing.assert_array_equal(r_on[k], r_off[k])
    assert r_on.stats.segments_pruned > 0, "nothing pruned"
    assert r_off.stats.segments_pruned == 0
    wm = r_on.watermark
    thr_on = wm / _median(walls[True])
    thr_off = wm / _median(walls[False])
    emit(FIG, "prune_on_rows_s", thr_on, "rows/s",
         f"selective id<2% scan; {r_on.stats.segments_pruned}/"
         f"{r_on.stats.segments} segments pruned, "
         f"rows_scanned={r_on.stats.rows_scanned}/{wm}")
    emit(FIG, "prune_off_rows_s", thr_off, "rows/s",
         "same query, pruning disabled; rows_scanned="
         f"{r_off.stats.rows_scanned}")
    ratio = thr_on / thr_off
    emit(FIG, "prune_speedup", ratio, "ratio",
         "acceptance at full scale: >= 2x on the selective predicate")
    if total >= 20_000:
        assert ratio >= 2.0, ratio
    return h


def bench_under_ingestion(mgr, total, batch, spill_dir):
    nbase = len(mgr.refstore["safety_levels"])
    upd = RollingUpdater(mgr.refstore["safety_levels"], nbase, 0.1,
                         min(25, nbase))
    h = mgr.submit(q1_store_plan(
        SyntheticAdapter(total=total, frame_size=batch, seed=13,
                         rate=20_000.0),
        "qp-live", batch, spill_dir=spill_dir, segment_rows=2000,
        refresh=RepairSpec(budget_rows_s=20_000.0),
        compact=CompactionSpec(budget_rows_s=100_000.0,
                               min_dead_frac=0.2, interval_s=0.1)))
    upd.start()
    lat, lag = [], []
    checks = 0
    last_live = -1
    while h.intake is not None and h.intake.is_alive():
        from repro.core import StoreSnapshot
        with StoreSnapshot(h.storage) as snap:
            t0 = time.perf_counter()
            r = group_query(h).execute(snapshot=snap)
            lat.append(time.perf_counter() - t0)
            ingested = h.intake.records_in
            live = snap.live_rows
            # pruned and unpruned must agree on the SAME snapshot even
            # while ingest/repair/compaction mutate the partitions
            r2 = group_query(h).execute(prune=False, snapshot=snap)
        for k in r:
            np.testing.assert_array_equal(r[k], r2[k])
        assert live >= last_live, "live rows went backwards"
        last_live = live
        lag.append(max(0, ingested - live))
        checks += 1
        time.sleep(0.02)
    s = join_quiesced(h, upd)
    assert s.stored == total, (s.stored, total)
    if total <= 10_000:
        naive_check(h.storage, "safety_level", 0)      # smoke-scale oracle
    final = group_query(h).execute()
    assert int(np.sum(final["n"])) == total
    lat.sort()
    emit(FIG, "live_query_p50_ms",
         1e3 * lat[len(lat) // 2] if lat else 0.0, "ms",
         f"{checks} queries during ingest @20K rec/s with rolling ref "
         "updates; repair+compaction active")
    emit(FIG, "live_query_p95_ms",
         1e3 * lat[min(len(lat) - 1, int(0.95 * len(lat)))] if lat
         else 0.0, "ms",
         f"repaired={s.repaired_rows} compacted={s.compacted_rows}")
    emit(FIG, "live_visibility_lag_rows",
         float(_median(lag)) if lag else 0.0, "rows",
         "median (ingested - visible-in-snapshot) at query time: the "
         "freshness cost of querying mid-ingestion")


def bench_compaction(mgr, total, batch, spill_dir, reps=5):
    nbase = len(mgr.refstore["safety_levels"])
    upd = RollingUpdater(mgr.refstore["safety_levels"], nbase, 0.05,
                         min(25, nbase), seed=23)
    h = mgr.submit(q1_store_plan(
        SyntheticAdapter(total=total, frame_size=batch, seed=17,
                         rate=15_000.0),
        "qp-churn", batch, spill_dir=spill_dir, segment_rows=2000,
        refresh=RepairSpec(budget_rows_s=1e6),
        compact=CompactionSpec(budget_rows_s=1e6, min_dead_frac=1.0)))
    upd.start()                 # frac 1.0: the job all but idles until the
    #                             measured drain below (only a 100%-dead
    #                             unit would trigger early)
    s = join_quiesced(h, upd)
    assert s.stored == total
    h.storage.flush()
    dead = h.storage.dead_rows
    assert dead > 0, "churn produced no superseded versions"
    q = group_query(h)
    before = q.execute()
    walls_b = [q.execute().stats.wall_s for _ in range(reps)]
    t0 = time.perf_counter()
    assert h.compaction.drain(timeout=600)
    reclaim_s = time.perf_counter() - t0
    assert h.storage.dead_rows == 0            # acceptance: 100% reclaimed
    assert h.compaction.stats.rows_dropped >= dead
    after = q.execute()
    for k in before:                           # acceptance: identical
        np.testing.assert_array_equal(before[k], after[k])
    assert after.stats.rows_scanned == before.stats.rows_scanned - dead
    walls_a = [q.execute().stats.wall_s for _ in range(reps)]
    emit(FIG, "churned_dead_rows", dead, "rows",
         f"superseded versions after repair churn over {total} rows "
         f"({100.0 * dead / (total + dead):.1f}% of stored versions)")
    emit(FIG, "compaction_reclaim_s", reclaim_s, "s",
         "drain to 0 dead rows (100% reclaim asserted); segments "
         f"rewritten={h.compaction.stats.segments_compacted}")
    emit(FIG, "scan_before_compact_ms", 1e3 * _median(walls_b), "ms",
         f"full-scan group-by over {before.stats.rows_scanned} row "
         f"versions ({before.stats.units} units)")
    emit(FIG, "scan_after_compact_ms", 1e3 * _median(walls_a), "ms",
         f"same query over {after.stats.rows_scanned} live rows "
         f"({after.stats.units} units; unit count is unchanged — "
         "in-place rewrites keep segment boundaries; the merged_read "
         "section measures what leveled merging buys on top)")


def bench_merged_read_path(mgr, total, batch, spill_dir, merge=True,
                           batched=True, reps=7):
    """The tentpole A/B: leveled merging x batched aggregation against
    the eager-per-unit / unmerged read path on the same data."""
    from repro.core import CompactionJob

    seg_rows = min(2000, max(total // 24, 100))
    h = mgr.submit(q1_store_plan(
        SyntheticAdapter(total=total, frame_size=batch, seed=19),
        "qp-merge", batch, spill_dir=spill_dir, segment_rows=seg_rows))
    s = h.join(timeout=1200)
    assert s.stored == total, (s.stored, total)
    h.storage.flush()

    # selective non-clustered predicate + grouped aggregation: zone maps
    # cannot prune it, so the cost is per-unit decompression + dispatch —
    # exactly what merging and batching attack
    q = (h.query().where(col("safety_level") >= 3)
         .group_by("safety_level")
         .agg(n=agg.count(), s=agg.sum("created_at"),
              top=agg.topk("safety_level", 2, payload="id")))

    def measure(batched_flag):
        r = q.execute(batched=batched_flag)
        walls = [q.execute(batched=batched_flag).stats.wall_s
                 for _ in range(reps)]
        return _median(walls), r

    base_w, base_r = measure(False)        # the pre-overhaul read path
    emit(FIG, "unmerged_eager_scan_ms", 1e3 * base_w, "ms",
         f"eager per-unit aggregation over {base_r.stats.units} units "
         f"({seg_rows}-row segments); dispatches="
         f"{base_r.stats.agg_invocations}")
    if batched:
        bat_w, bat_r = measure(True)
        for k in base_r:
            np.testing.assert_array_equal(base_r[k], bat_r[k])
        emit(FIG, "batched_agg_speedup", base_w / bat_w, "ratio",
             f"one-dispatch batched aggregation, same layout: "
             f"{bat_r.stats.agg_batched_units} units folded into "
             f"{bat_r.stats.agg_invocations} dispatches "
             f"(kernel={bat_r.stats.agg_kernel_dispatches}, "
             f"fallback={bat_r.stats.agg_fallback_dispatches}, "
             f"64bit={bat_r.stats.agg_64bit_fallbacks})")
    if merge:
        segs_before = h.storage.segment_count
        job = CompactionJob(h.storage, CompactionSpec(
            budget_rows_s=1e6, merge_fanin=8,
            level_target_rows=8 * seg_rows))
        job.merge_now(min_run=2)
        segs_after = h.storage.segment_count
        assert segs_after < segs_before, "merge_now merged nothing"
        emit(FIG, "segments_before_merge", segs_before, "segments",
             f"{seg_rows}-row flush-size segments across "
             f"{len(h.storage.partitions)} partitions")
        emit(FIG, "segments_after_merge", segs_after, "segments",
             f"levels={h.storage.level_histogram()}; "
             f"{job.stats.merges} merges consumed "
             f"{job.stats.segments_merged} segments")
        merged_w, merged_r = measure(batched)
        for k in base_r:                   # acceptance: identical
            np.testing.assert_array_equal(base_r[k], merged_r[k])
        ratio = base_w / merged_w
        emit(FIG, "merged_scan_speedup", ratio, "ratio",
             f"merged{'+batched' if batched else ''} "
             f"({merged_r.stats.units} units) vs unmerged eager "
             f"({base_r.stats.units} units); acceptance at full "
             "scale: >= 1.5x")
        if total >= 20_000 and batched:
            assert ratio >= 1.5, ratio
    return h


def main(total: int = 60_000, batch: int = BATCH_1X, merge: bool = True,
         batched: bool = True) -> None:
    mgr = make_manager(scale=0.02)
    work = tempfile.mkdtemp(prefix="fig_query_")
    try:
        bench_scan_pruning(mgr, total, batch, f"{work}/prune")
        bench_under_ingestion(mgr, max(total // 3, 4 * batch), batch,
                              f"{work}/live")
        bench_compaction(mgr, max(total // 3, 4 * batch), batch,
                         f"{work}/churn")
        bench_merged_read_path(mgr, total, batch, f"{work}/merge",
                               merge=merge, batched=batched)
    finally:
        shutil.rmtree(work, ignore_errors=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--total", type=int, default=60_000)
    ap.add_argument("--batch", type=int, default=BATCH_1X)
    ap.add_argument("--merge", choices=("on", "off"), default="on",
                    help="A/B axis: leveled segment merging in the "
                         "merged_read section")
    ap.add_argument("--batched-agg", choices=("on", "off"), default="on",
                    help="A/B axis: one-dispatch batched aggregation in "
                         "the merged_read section")
    ap.add_argument("--json-out", default="BENCH_fig_query.json",
                    help="machine-readable metrics file "
                         "(empty string disables)")
    args = ap.parse_args()
    main(args.total, args.batch, merge=args.merge == "on",
         batched=args.batched_agg == "on")
    if args.json_out:
        write_json(FIG, args.json_out)
