"""Fig 24 — basic ingestion (no UDF): 'current feeds' (coupled, single
parsing node) vs 'balanced current feeds' (parsing spread) vs the new
framework at 1X/4X/16X batch sizes, plus the Approach-1 INSERT baseline
(per-statement recompilation).

Paper claims reproduced: (1) larger batches -> fewer computing-job
invocations -> higher throughput; (2) decoupling parse from storage beats
the coupled single-intake pipeline; (3) repeated INSERT pays compilation
per statement and is far slower."""

from __future__ import annotations

from benchmarks.common import (BATCH_1X, BATCH_4X, BATCH_16X, emit,
                               make_manager, run_feed)

FIG = "fig24"


def main(total: int = 20_000) -> None:
    mgr = make_manager()

    for label, batch in (("new_1X", BATCH_1X), ("new_4X", BATCH_4X),
                         ("new_16X", BATCH_16X)):
        s = run_feed(mgr, f"f24-{label}", total, batch, udf=None,
                     framework="new", partitions=2)
        emit(FIG, f"{label}_records_per_s", s.records_per_s, "rec/s",
             f"invocations={s.computing.invocations}")
        emit(FIG, f"{label}_parse_s", s.computing.parse_s, "s")

    s = run_feed(mgr, "f24-current", total, BATCH_1X, udf=None,
                 framework="current", partitions=1)
    emit(FIG, "current_records_per_s", s.records_per_s, "rec/s",
         "single intake node parses everything")

    s = run_feed(mgr, "f24-balanced", total, BATCH_1X, udf=None,
                 framework="balanced", partitions=2)
    emit(FIG, "balanced_records_per_s", s.records_per_s, "rec/s",
         "parsing spread over partitions")

    # Approach-1 INSERT vs predeployed: visible only with a UDF attached
    # (the compiled artifact is the enrichment plan).  Same workload both
    # ways, small slice (the INSERT path recompiles every statement).
    from repro.core.enrich import queries as Q
    ins_total = max(BATCH_1X * 4, total // 10)
    s = run_feed(mgr, "f24-insert-q1", ins_total, BATCH_1X, udf=Q.Q1,
                 framework="insert")
    emit(FIG, "insert_q1_records_per_s", s.records_per_s, "rec/s",
         f"{ins_total} records, jit recompiled per statement")
    s = run_feed(mgr, "f24-new-q1", ins_total, BATCH_1X, udf=Q.Q1,
                 framework="new", partitions=1)
    emit(FIG, "new_q1_records_per_s", s.records_per_s, "rec/s",
         f"predeployed: compiles={s.predeploy['compiles']}, "
         f"invocations={s.computing.invocations}")


if __name__ == "__main__":
    main()
