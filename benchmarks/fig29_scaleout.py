"""Fig 29 — scale-out (kX data on kX 'nodes'): weak scaling.

Reproduced mechanism: with data and partitions scaled together, per-
partition work is constant; the measured quantity is the per-record
processing time at k=1 vs k=2 partitions on the real pipeline (thread-
level), plus the derived weak-scaling curve from the fig28 decomposition.
The paper's claim — ingestion time stays ~flat as (data, nodes) scale
together, with mild growth from coordination overhead — shows up here as
the per-record time ratio staying near 1."""

from __future__ import annotations

from benchmarks.common import BATCH_1X, emit, make_manager, run_feed
from repro.core.enrich import queries as Q

FIG = "fig29"
UDFS = {"q4": Q.Q4, "q5": Q.Q5, "q7": Q.Q7}


def main(base_total: int = 2_000) -> None:
    mgr = make_manager(scale=0.02)
    for qname, udf in UDFS.items():
        per_rec = {}
        for k in (1, 2):
            s = run_feed(mgr, f"f29-{qname}-{k}x", base_total * k,
                         BATCH_1X, udf=udf, framework="new", partitions=k)
            per_rec[k] = s.wall_s / (base_total * k)
            emit(FIG, f"{qname}_{k}x_ms_per_record", per_rec[k] * 1e3,
                 "ms/rec", f"partitions={k} records={base_total * k}")
        emit(FIG, f"{qname}_weak_scaling_ratio", per_rec[2] / per_rec[1],
             "x", "1.0 = perfect weak scaling (single-core: pipeline "
             "overlap only)")


if __name__ == "__main__":
    main()
