"""Threshold regression gate over the BENCH_*.json metric files.

The fig drivers emit ``BENCH_<fig>.json`` (``--json-out``); this script
checks each file against per-figure thresholds and exits non-zero on any
miss — the bench-smoke CI job runs it after the drivers, so a refactor
that silently rots a measurement path (repair stops converging, pruning
stops pruning, the elasticity controller stops tracking bursts) fails
the build instead of rotting a CSV nobody reads.

Two profiles:

``--profile smoke``
    CI row counts on a shared single-core runner: only *correctness*
    metrics get tight bounds (convergence mismatches MUST be zero);
    ratios that compare two timed runs get loose floors — at smoke scale
    they mostly detect "the axis broke entirely", not perf drift.

``--profile full``
    Paper-scale local runs: the ratio floors tighten to the values the
    figures actually claim (interference isolation, zone-map pruning
    speedup, elastic-vs-static capacity).

A threshold is ``(metric, op, bound)``; a listed metric missing from the
file is itself a failure (presence is part of the contract — drivers
renaming a metric must update this gate and the figure docs together).

Usage::

    python benchmarks/regression_gate.py --profile smoke \
        BENCH_fig_repair.json BENCH_fig_query.json BENCH_fig25.json
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Tuple

# (metric, op, bound): op is one of <=, >=, == (exact, for counts).
Threshold = Tuple[str, str, float]

THRESHOLDS: Dict[str, Dict[str, List[Threshold]]] = {
    "smoke": {
        "fig_repair": [
            # repair MUST converge the store to the final ref snapshot —
            # scale-independent correctness, not a perf number
            ("currency_converged_mismatches", "==", 0),
            # repair on vs off throughput: loose floor (smoke noise)
            ("interference_ratio", ">=", 0.3),
        ],
        "fig_query": [
            # zone-map pruning must at least not LOSE to full scans
            ("prune_speedup", ">=", 0.5),
            # snapshot scans under ingestion stay bounded (smoke: just
            # "finite and sane", the figure claims the real bound)
            ("live_query_p95_ms", "<=", 10_000),
            # the read-path overhaul axes must not LOSE to the eager /
            # unmerged path even at smoke scale (presence enforced: a
            # driver that silently drops the merged_read section fails)
            ("batched_agg_speedup", ">=", 0.5),
            ("merged_scan_speedup", ">=", 0.5),
        ],
        "fig25": [
            # the controller must reach a usable fraction of the best
            # static allocation even on a noisy shared core
            ("bursty_elastic_vs_best_static", ">=", 0.3),
            # tracing on vs off: interleaved-median ratio, same floor at
            # every scale — observability must stay ~free
            ("obs_overhead_ratio", ">=", 0.97),
            # the FULL feedscope surface (profiler + health + scraped
            # live endpoint) vs metrics-only, same floor at every scale;
            # presence enforced, so CI must run fig25 with --profile
            ("profile_overhead_ratio", ">=", 0.97),
        ],
        "fig_recovery": [
            # exactly-once across SIGKILL/restart is scale-independent
            # correctness: zero at every scale, no looseness
            ("rows_lost_total", "==", 0),
            ("rows_duplicated_total", "==", 0),
            # recovery must complete, but a shared runner gets slack
            ("recovery_max_s", "<=", 120),
            # WAL-on vs WAL-off throughput: loose smoke floor
            ("durable_throughput_ratio", ">=", 0.3),
        ],
    },
    "full": {
        "fig_repair": [
            ("currency_converged_mismatches", "==", 0),
            # budgeted repair should barely dent ingest capacity
            ("interference_ratio", ">=", 0.9),
        ],
        "fig_query": [
            ("prune_speedup", ">=", 2.0),
            ("live_query_p95_ms", "<=", 500),
            # acceptance: merged + batched selective scan beats the
            # pre-overhaul read path by 1.5x at 2K-row segments; the
            # batched axis alone must never regress the eager path
            ("batched_agg_speedup", ">=", 1.0),
            ("merged_scan_speedup", ">=", 1.5),
        ],
        "fig25": [
            ("bursty_elastic_vs_best_static", ">=", 0.9),
            ("obs_overhead_ratio", ">=", 0.97),
            ("profile_overhead_ratio", ">=", 0.97),
        ],
        "fig_recovery": [
            ("rows_lost_total", "==", 0),
            ("rows_duplicated_total", "==", 0),
            ("recovery_max_s", "<=", 30),
            # the WAL at default interval fsync costs <= 10% of
            # steady-state ingest (final-checkpoint drain excluded)
            ("durable_throughput_ratio", ">=", 0.9),
        ],
    },
}

_OPS = {
    "<=": lambda v, b: v <= b,
    ">=": lambda v, b: v >= b,
    "==": lambda v, b: v == b,
}


def check_file(path: str, profile: str) -> List[str]:
    """Return human-readable failure strings for one BENCH_*.json."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable ({e})"]
    fig = doc.get("fig")
    metrics = doc.get("metrics")
    if not isinstance(metrics, dict):
        return [f"{path}: no 'metrics' object"]
    thresholds = THRESHOLDS[profile].get(fig)
    if thresholds is None:
        return [f"{path}: unknown fig {fig!r} (gate has no thresholds; "
                "add them to benchmarks/regression_gate.py)"]
    fails = []
    for name, op, bound in thresholds:
        if name not in metrics:
            fails.append(f"{path}: required metric {name!r} missing")
            continue
        value = metrics[name]["value"]
        if not isinstance(value, (int, float)):
            fails.append(f"{path}: {name} is non-numeric ({value!r})")
        elif not _OPS[op](value, bound):
            fails.append(f"{path}: {name} = {value} violates "
                         f"'{op} {bound}' ({profile} profile)")
    return fails


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("files", nargs="+", help="BENCH_*.json files")
    ap.add_argument("--profile", choices=sorted(THRESHOLDS),
                    default="smoke")
    args = ap.parse_args(argv)
    failures: List[str] = []
    for path in args.files:
        failures.extend(check_file(path, args.profile))
    for f in failures:
        print(f"GATE FAIL {f}")
    n = len(args.files)
    if not failures:
        print(f"regression gate: {n} file(s) pass the "
              f"{args.profile} profile")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
