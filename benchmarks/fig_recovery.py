"""fig_recovery — fault-injection harness for durable feeds
(core/durability.py + core/recovery.py), the recovery axis the paper's
experiments assume but never measure: SIGKILL a process mid-ingest (with
rolling reference updates in flight), restart, and demand exactly-once.

Sections:

  kill-restart  ``--kills`` rounds: a child process runs a durable Q1
                feed (WAL + coordinated checkpoints) against a throttled
                synthetic stream while a rolling updater upserts
                safety_levels keys; the parent SIGKILLs it at a random
                point of the ingest window, then recovers the feed
                in-process (``FeedManager.resume``) from the surviving
                durable directory.  Hard asserts per round: rows lost
                = 0 and rows duplicated = 0 over the full stream.
                Emits the replay backlog and the recovery time (resume
                call until the replayed backlog is re-stored) per round,
                plus max/mean aggregates.

  throughput    durable (default interval fsync) vs non-durable ingest
                of the same stream, both spilling to disk, interleaved
                warm/steady rounds.  Emits the ratio; acceptance: the
                WAL costs <= 10% steady-state throughput at paper-scale
                runs (smoke-scale floor is looser — see
                benchmarks/regression_gate.py).

The child re-enters this module with ``--child``; the crash is a real
SIGKILL of a separate interpreter, so no Python-level cleanup (atexit,
finally, flush-on-close) can soften it.
"""

from __future__ import annotations

import argparse
import os
import shutil
import subprocess
import sys
import time
from collections import Counter

import numpy as np

from benchmarks.common import BATCH_1X, emit, make_manager, write_json
from benchmarks.fig_repair import RollingUpdater
from repro.core import (CompactionSpec, DurableSpec, RepairSpec,
                        SyntheticAdapter, pipeline)
from repro.core.enrich import queries as Q

FIG = "fig_recovery"


def durable_plan(durable_dir: str, total: int, batch: int, seed: int,
                 rate, name: str, refresh=None):
    """The plan both sides build: the child runs it, the parent resumes
    it — recovery requires the identical deterministic plan (same seed,
    same frame size), modulo the rate limit (replay is unthrottled)."""
    return (pipeline(SyntheticAdapter(total=total, frame_size=batch,
                                      seed=seed, rate=rate), name)
            .parse(batch_size=batch)
            .options(num_partitions=2, holder_capacity=16)
            .enrich(Q.Q1)
            # small flush segments + an aggressive leveled-merge policy:
            # segment merges rewrite the store WHILE the kill window is
            # open, so every crash image also stresses the merge path's
            # manifest-before-GC ordering (exactly-once must still hold)
            .store(segment_rows=500, sort_key="country",
                   compact=CompactionSpec(budget_rows_s=1e6,
                                          interval_s=0.05,
                                          yield_backlog_batches=1e9,
                                          merge_fanin=4,
                                          level_target_rows=100_000),
                   durable=DurableSpec(dir=durable_dir,
                                       fsync="interval",
                                       fsync_interval_s=0.02,
                                       checkpoint_interval_s=0.3),
                   refresh=refresh))


def stored_id_counts(storage) -> Counter:
    """LIVE occurrence count per primary key across all partitions.
    Physical dead rows (repair re-appends; compaction reclaims) are not
    duplicates — but the same pk live in two partitions, or twice in
    one, is exactly the row-double-delivery a replay bug would produce."""
    counts: Counter = Counter()
    for part in storage.partitions:
        snap = part.snapshot_view()
        try:
            for u in snap.units:
                ids = np.asarray(u.read(("id",))["id"])
                for i in ids[snap.live_mask(ids, u.base)]:
                    counts[int(i)] += 1
        finally:
            snap.release()
    return counts


# ---------------------------------------------------------------------------
# child: the process that gets killed
# ---------------------------------------------------------------------------

def child_main(args) -> None:
    mgr = make_manager(scale=0.02)
    nbase = len(mgr.refstore["safety_levels"])
    upd = RollingUpdater(mgr.refstore["safety_levels"], nbase,
                         args.update_every,
                         min(args.update_keys, nbase))
    h = mgr.submit(durable_plan(
        args.durable_dir, args.total, args.batch, args.seed, args.rate,
        args.name, refresh=RepairSpec(budget_rows_s=20_000)))
    upd.start()
    print("READY", flush=True)
    h.join(timeout=1200)
    upd.stop()
    print("DONE", flush=True)


# ---------------------------------------------------------------------------
# parent: kill, restart, verify exactly-once
# ---------------------------------------------------------------------------

def run_round(rnd: int, dur_dir: str, total: int, batch: int, rate: float,
              update_every: float, update_keys: int, rng) -> dict:
    name = f"rec{rnd}"
    seed = 100 + rnd
    cmd = [sys.executable, "-m", "benchmarks.fig_recovery", "--child",
           "--durable-dir", dur_dir, "--total", str(total),
           "--batch", str(batch), "--seed", str(seed),
           "--rate", str(rate), "--name", name,
           "--update-every", str(update_every),
           "--update-keys", str(update_keys), "--json-out", ""]
    proc = subprocess.Popen(cmd, stdout=subprocess.PIPE, text=True)
    try:
        for line in proc.stdout:
            if line.startswith("READY"):
                break
        else:
            raise RuntimeError(f"round {rnd}: child died before READY "
                               f"(rc={proc.wait()})")
        # kill at a random point of the nominal ingest window
        window = total / rate
        delay = rng.uniform(0.1 * window, 0.8 * window)
        time.sleep(delay)
    finally:
        proc.kill()
        proc.wait()
        proc.stdout.close()

    # restart: fresh manager, fresh (pristine) ref tables, same plan
    mgr = make_manager(scale=0.02)
    plan = durable_plan(dur_dir, total, batch, seed, None, name)
    t0 = time.perf_counter()
    h = mgr.resume(plan)
    rt = h.durability
    backlog = rt.replayed_records
    # recovery time = resume() until the replayed backlog is re-stored
    # (the checkpoint watermark reaches the pre-crash WAL tail)
    while rt.ledger.watermark() < rt.replay_target_seq:
        time.sleep(0.005)
    recovery_s = time.perf_counter() - t0
    h.join(timeout=600)           # raises if any job errored

    counts = stored_id_counts(h.storage)
    lost = total - len(counts)
    dups = sum(c - 1 for c in counts.values())
    assert lost == 0, (f"round {rnd}: {lost} rows lost after kill at "
                       f"+{delay:.2f}s (backlog={backlog})")
    assert dups == 0, (f"round {rnd}: {dups} duplicate rows after kill "
                       f"at +{delay:.2f}s (backlog={backlog})")
    return {"kill_after_s": delay, "backlog": backlog,
            "recovery_s": recovery_s, "lost": lost, "dups": dups}


def bench_kill_restart(base_dir: str, kills: int, total: int, batch: int,
                       rate: float, update_every: float,
                       update_keys: int) -> None:
    rng = np.random.default_rng(29)
    rounds = []
    for rnd in range(kills):
        dur_dir = os.path.join(base_dir, f"round{rnd}")
        try:
            r = run_round(rnd, dur_dir, total, batch, rate,
                          update_every, update_keys, rng)
        finally:
            shutil.rmtree(dur_dir, ignore_errors=True)
        rounds.append(r)
        emit(FIG, f"recovery_round{rnd}_s", r["recovery_s"], "s",
             f"kill at +{r['kill_after_s']:.2f}s, replay backlog "
             f"{r['backlog']} records, lost={r['lost']} dups={r['dups']}")
    emit(FIG, "kills", len(rounds), "count",
         f"SIGKILL rounds over a {total}-row stream @{rate:.0f} rec/s "
         f"with rolling ref updates every {update_every}s")
    emit(FIG, "rows_lost_total", sum(r["lost"] for r in rounds), "rows",
         "exactly-once: must be 0")
    emit(FIG, "rows_duplicated_total", sum(r["dups"] for r in rounds),
         "rows", "exactly-once: must be 0")
    emit(FIG, "backlog_max_records",
         max(r["backlog"] for r in rounds), "records",
         "largest WAL tail replayed on restart")
    rec = [r["recovery_s"] for r in rounds]
    emit(FIG, "recovery_max_s", max(rec), "s",
         "resume() -> backlog re-stored, worst round")
    emit(FIG, "recovery_mean_s", sum(rec) / len(rec), "s", "")


# ---------------------------------------------------------------------------
# throughput: the price of the WAL at default fsync
# ---------------------------------------------------------------------------

def bench_throughput(base_dir: str, total: int, batch: int) -> None:
    mgr = make_manager(scale=0.02)
    samples = {"plain": [], "durable": []}
    # rounds interleave plain/durable so slow system drift (page cache,
    # XLA autotuning, thermal) hits both sides equally; the emitted
    # number is the per-side MEDIAN of the steady rounds
    for rnd in ("warm", "steady1", "steady2", "steady3"):
        for label in ("plain", "durable"):
            name = f"tp-{label}-{rnd}"
            adapter = SyntheticAdapter(total=total, frame_size=batch,
                                       seed=23)
            spill = os.path.join(base_dir, name)
            if label == "durable":
                p = (pipeline(adapter, name)
                     .parse(batch_size=batch)
                     .options(num_partitions=2, holder_capacity=16)
                     .enrich(Q.Q1)
                     .store(durable=DurableSpec(dir=spill)))
            else:
                p = (pipeline(adapter, name)
                     .parse(batch_size=batch)
                     .options(num_partitions=2, holder_capacity=16)
                     .enrich(Q.Q1)
                     .store(spill_dir=spill))
            h = mgr.submit(p)
            s = h.join(timeout=1200)
            assert s.stored == total, (name, s.stored, total)
            shutil.rmtree(spill, ignore_errors=True)
            if rnd != "warm":
                # steady-state ingest rate: the final coordinated
                # checkpoint (flush + snapshot at join) is shutdown
                # drain, excluded like fig_repair's repair_drain_s
                ingest_s = s.wall_s - s.durable_finish_s
                samples[label].append(s.records_in / ingest_s
                                      if ingest_s else 0.0)
    res = {}
    for label, xs in samples.items():
        res[label] = sorted(xs)[len(xs) // 2]
        emit(FIG, f"throughput_{label}", res[label], "rec/s",
             f"unthrottled x{total} rows, both spilling to disk, "
             f"median of {len(xs)} interleaved steady rounds, "
             "final-checkpoint drain excluded")
    emit(FIG, "durable_throughput_ratio", res["durable"] / res["plain"],
         "ratio", "acceptance (full profile): >= 0.9 at default "
         "interval fsync")


def main(base_dir: str, kills: int, total: int, batch: int, rate: float,
         update_every: float, update_keys: int) -> None:
    if kills > 0:               # --kills 0: throughput-only run
        bench_kill_restart(base_dir, kills, total, batch, rate,
                           update_every, update_keys)
    bench_throughput(base_dir, total, batch)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--child", action="store_true",
                    help=argparse.SUPPRESS)
    ap.add_argument("--durable-dir", default="",
                    help=argparse.SUPPRESS)
    ap.add_argument("--seed", type=int, default=0,
                    help=argparse.SUPPRESS)
    ap.add_argument("--name", default="rec", help=argparse.SUPPRESS)
    ap.add_argument("--kills", type=int, default=5,
                    help="SIGKILL/restart rounds")
    ap.add_argument("--total", type=int, default=40_000)
    ap.add_argument("--batch", type=int, default=BATCH_1X)
    ap.add_argument("--rate", type=float, default=6000.0,
                    help="child ingest throttle (rec/s) — sets the kill "
                         "window; replay on resume is unthrottled")
    ap.add_argument("--update-every", type=float, default=0.1,
                    help="seconds between rolling ref upserts (child)")
    ap.add_argument("--update-keys", type=int, default=25,
                    help="keys upserted per rolling update")
    ap.add_argument("--work-dir", default="",
                    help="durable-dir root (default: a temp dir)")
    ap.add_argument("--json-out", default="BENCH_fig_recovery.json",
                    help="machine-readable metrics file "
                         "(empty string disables)")
    args = ap.parse_args()
    if args.child:
        child_main(args)
    else:
        import tempfile
        base = args.work_dir or tempfile.mkdtemp(prefix="fig_recovery_")
        try:
            main(base, args.kills, args.total, args.batch, args.rate,
                 args.update_every, args.update_keys)
        finally:
            if not args.work_dir:
                shutil.rmtree(base, ignore_errors=True)
        if args.json_out:
            write_json(FIG, args.json_out)
