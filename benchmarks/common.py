"""Shared benchmark scaffolding.

This container is a single CPU core, so absolute wall-clock is meaningless
vs. the paper's 6-24 node cluster; what reproduces are the paper's
*relative* claims (batch-size effects, framework overhead decomposition,
UDF complexity ordering).  Where the paper scales nodes, we measure the
per-invocation overhead + per-record compute directly and report the
derived scaling model alongside the measured single-core wall time —
labeled as such.  Every figure emits CSV rows: name,value,unit,notes.
"""

from __future__ import annotations

import contextlib
import csv
import json
import time
from typing import Dict, List

from repro.core import (FeedConfig, FeedManager, RefStore,
                        SyntheticAdapter, pipeline)
from repro.core.enrich import queries as Q
from repro.kernels import DISPATCH_MODES, set_dispatch_mode

ROWS: List[Dict] = []

# paper batch sizes
BATCH_1X, BATCH_4X, BATCH_16X = 420, 1680, 6720


def emit(fig: str, name: str, value, unit: str, notes: str = "") -> None:
    row = {"fig": fig, "name": name, "value": round(value, 6)
           if isinstance(value, float) else value, "unit": unit,
           "notes": notes}
    ROWS.append(row)
    print(f"{fig},{name},{row['value']},{unit},{notes}", flush=True)


def write_json(fig: str, path: str) -> None:
    """Machine-readable counterpart of the CSV stream: one document per
    figure, metrics keyed by name — the input format of
    benchmarks/regression_gate.py (the CI threshold gate)."""
    metrics = {r["name"]: {"value": r["value"], "unit": r["unit"],
                           "notes": r["notes"]}
               for r in ROWS if r["fig"] == fig}
    with open(path, "w") as f:
        json.dump({"fig": fig, "metrics": metrics}, f, indent=2,
                  sort_keys=True)
        f.write("\n")


def write_csv(path: str) -> None:
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=["fig", "name", "value", "unit",
                                          "notes"])
        w.writeheader()
        w.writerows(ROWS)


def make_manager(scale: float = 0.02, overrides=None) -> FeedManager:
    store = RefStore()
    Q.make_reference_tables(store, scale=scale, seed=7,
                            scale_overrides=overrides)
    return FeedManager(store)


def add_dispatch_arg(parser) -> None:
    """The --dispatch axis shared by the enrichment benchmarks: route
    operators through the Pallas kernels or the jnp reference paths (see
    core/enrich/dispatch.py).  Off-TPU the pallas path runs in interpret
    mode — an emulator, so absolute numbers are meaningless there; on TPU
    it is the production path."""
    parser.add_argument("--dispatch", choices=DISPATCH_MODES,
                        default="auto",
                        help="kernel dispatch mode (default: auto)")


def set_dispatch(mode: str) -> None:
    set_dispatch_mode(mode)


def run_feed(mgr: FeedManager, name: str, total: int, batch: int,
             udf=None, framework: str = "new", partitions: int = 2,
             model: str = "per_batch", refresh: str = "always",
             coalesce_rows=None):
    """coalesce_rows=None is the production default (auto: on for the
    decoupled framework); pass 0 for exact-invocation comparisons.
    framework="new" builds a plan (the shim lowering is gone); the
    coupled/insert baselines keep their cfg-driven measurement rigs."""
    adapter = SyntheticAdapter(total=total, frame_size=batch, seed=11)
    if framework == "new":
        p = (pipeline(adapter, name)
             .parse(batch_size=batch, model=model, refresh=refresh)
             .options(num_partitions=partitions,
                      coalesce_rows=coalesce_rows))
        if udf is not None:
            p.enrich(udf)
        h = mgr.submit(p.store())
    else:
        cfg = FeedConfig(name=name, udf=udf, batch_size=batch,
                         num_partitions=partitions, framework=framework,
                         model=model, refresh=refresh,
                         coalesce_rows=coalesce_rows)
        h = mgr.start(cfg, adapter)
    stats = h.join(timeout=1200)
    assert stats.stored == total, (name, stats.stored, total)
    return stats


@contextlib.contextmanager
def timed():
    t = {}
    t0 = time.perf_counter()
    yield t
    t["s"] = time.perf_counter() - t0
