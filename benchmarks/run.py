"""Benchmark harness entrypoint (deliverable d): one module per paper
figure, plus the roofline summary derived from the dry-run artifacts.

    PYTHONPATH=src python -m benchmarks.run [--fig figNN] [--full]

Emits CSV rows (fig,name,value,unit,notes) to stdout and
benchmarks/results.csv.  Absolute numbers are single-CPU-core wall clock;
the reproduced claims are the relative effects (see benchmarks/common.py).
"""

from __future__ import annotations

import argparse
import sys
import time

from benchmarks import common
from benchmarks import fig24_basic_ingestion as f24
from benchmarks import fig25_udf_enrichment as f25
from benchmarks import fig26_udf_complexity as f26
from benchmarks import fig28_speedup as f28
from benchmarks import fig29_scaleout as f29
from benchmarks import roofline_report as froof


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fig", default=None,
                    help="run a single figure (fig24..fig29, roofline)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale record counts (slow on 1 core)")
    common.add_dispatch_arg(ap)
    args = ap.parse_args()
    common.set_dispatch(args.dispatch)

    k = 5 if args.full else 1
    figs = {
        "fig24": lambda: f24.main(total=20_000 * k),
        "fig25": lambda: f25.main(total=8_000 * k, dispatch=args.dispatch),
        "fig26": lambda: f26.main(total=4_000 * k),
        "fig28": lambda: f28.main(total=3_000 * k,
                                  dispatch=args.dispatch),
        "fig29": lambda: f29.main(base_total=2_000 * k),
        "roofline": froof.main,
    }
    todo = [args.fig] if args.fig else list(figs)
    print("fig,name,value,unit,notes")
    t0 = time.perf_counter()
    for name in todo:
        figs[name]()
    common.emit("all", "total_bench_wall", time.perf_counter() - t0, "s")
    common.write_csv("benchmarks/results.csv")
    return 0


if __name__ == "__main__":
    sys.exit(main())
